//! `dynasplit` — leader entrypoint + CLI.
//!
//! Subcommands (each maps to a DESIGN.md experiment or an operational
//! action):
//!
//! ```text
//! dynasplit space                      print Table-1 configuration spaces
//! dynasplit solve     [--net --trials --strategy --seed --out]
//! dynasplit store     export|import        versioned warm-restart store documents (§17)
//! dynasplit serve     [--net --requests --workers --policy --rate --adapt
//!                       --trace --metrics --report-json ...]
//! dynasplit trace     [--file --top]       replay a recorded flight-recorder trace
//! dynasplit adapt     [--net --requests]   closed-loop adaptation experiment
//! dynasplit throughput [--net --requests]   serving-pipeline experiment
//! dynasplit scale     [--requests --devices]  fleet-scale sweep (DESIGN.md §14)
//! dynasplit chaos     [--requests]         fault injection × recovery (DESIGN.md §15)
//! dynasplit prelim                     Fig. 2a-e
//! dynasplit bounds                     Table 2
//! dynasplit workload                   Fig. 5
//! dynasplit testbed   [--requests]     Fig. 6-9 + headline
//! dynasplit ablation                   Fig. 10
//! dynasplit simulate  [--requests]     Fig. 11-14
//! dynasplit overhead                   Fig. 15
//! dynasplit smallmodels                §2.2 finding (i)
//! dynasplit extensions                 §6.6 ablations
//! dynasplit accuracy                   measured backend accuracy table
//! dynasplit runtime-info               artifact load/compile statistics
//! ```

use anyhow::{bail, Result};

use dynasplit::adapt::{
    run_closed_loop, AdaptConfig, AdaptiveLoop, ConfigStore, DriftConfig, NetworkState,
    ResolveConfig, StoreDocument, StoreMap, Telemetry, WarmState,
};
use dynasplit::controller::{
    ConfigSet, EnergyBudgetPolicy, HysteresisPolicy, PaperPolicy, PerRequestSimExecutor,
    SchedulingPolicy, StrictDeadlinePolicy,
};
use dynasplit::experiments::{self, Ctx};
use dynasplit::model::Manifest;
use dynasplit::obs::{chrome, expose, FlightRecorder, Recorder};
use dynasplit::runtime::InferenceBackend;
use dynasplit::serve::{
    run_pipeline_resilient, PipelineConfig, RetryPolicy, ServeReport, StoreSource,
};
use dynasplit::solver::{Solver, SolverOutput, Strategy};
use dynasplit::space::{Network, Space};
use dynasplit::util::cli::{ArgSpec, Args};
use dynasplit::util::json::Json;
use dynasplit::util::rng::Pcg32;
use dynasplit::util::table::Table;
use dynasplit::workload::{mixed_timeline, ArrivalProcess, LatencyBounds, NetworkMix, WorkloadGen};

fn main() {
    if let Err(e) = run() {
        eprintln!("{e:#}");
        std::process::exit(1);
    }
}

fn spec(cmd: &str, about: &'static str) -> ArgSpec {
    ArgSpec::new(format!("dynasplit {cmd}"), about)
        .opt("artifacts", "artifacts", "artifact directory")
        .opt("seed", "42", "experiment seed")
        .opt("batch", "1000", "inferences averaged per trial")
}

fn run() -> Result<()> {
    let mut args = std::env::args().skip(1);
    let cmd = args.next().unwrap_or_else(|| "help".to_string());
    match cmd.as_str() {
        "space" => cmd_space(),
        "solve" => cmd_solve(),
        "store" => cmd_store(),
        "serve" => cmd_serve(),
        "trace" => cmd_trace(),
        "mixed" => cmd_mixed(),
        "adapt" => cmd_adapt(),
        "throughput" => cmd_throughput(),
        "scale" => cmd_scale(),
        "chaos" => cmd_chaos(),
        "prelim" => cmd_prelim(),
        "bounds" => cmd_bounds(),
        "workload" => cmd_workload(),
        "testbed" => cmd_testbed(),
        "ablation" => cmd_ablation(),
        "simulate" => cmd_simulate(),
        "overhead" => cmd_overhead(),
        "smallmodels" => cmd_smallmodels(),
        "extensions" => cmd_extensions(),
        "accuracy" => cmd_accuracy(),
        "runtime-info" => cmd_runtime_info(),
        "help" | "--help" | "-h" => {
            println!("{}", HELP);
            Ok(())
        }
        other => bail!("unknown subcommand {other:?}\n\n{HELP}"),
    }
}

const HELP: &str = "dynasplit — energy-aware split inference (paper reproduction)

subcommands:
  space          print the Table-1 configuration spaces
  solve          offline phase: search the space, save the pareto set
  store          warm-restart persistence (DESIGN.md §17): export/import versioned
                 store documents (fronts + epoch registry + calibration + telemetry;
                 `serve --store-in` then boots with zero offline solves)
  serve          online phase: concurrent serving pipeline (queue, policies, cache;
                 --mix vgg16=0.7,vit=0.3 serves both networks from one pipeline;
                 --adapt closes the loop: telemetry -> drift -> re-solve -> hot-swap;
                 --trace/--metrics/--report-json record the run: Chrome trace JSON,
                 Prometheus-style metrics text, machine-readable report)
  trace          replay a `serve --trace` recording: per-request waterfall +
                 span-stat table (DESIGN.md §16)
  mixed          mixed-network serving experiment (mix x workers x policy + mix shift)
  adapt          closed-loop adaptation experiment (mid-run world shift + QoS recovery)
  throughput     serving-pipeline throughput experiment (policies x workers x cache)
  scale          fleet-scale sweep: sharded admission x workers under a discrete-event
                 clock (heterogeneous device fleet, diurnal + flash-crowd arrivals)
  chaos          chaos serving: seeded fault scenarios (link flap, brownout, shard
                 outage) x recovery modes (none | retry | retry+breaker)
  prelim         Fig. 2a-e preliminary study
  bounds         Table 2 latency bounds
  workload       Fig. 5 QoS distributions
  testbed        Fig. 6-9 testbed experiment + headline numbers
  ablation       Fig. 10 20%-vs-80% search comparison
  simulate       Fig. 11-14 simulation experiment
  overhead       Fig. 15 controller overhead
  smallmodels    §2.2 finding (i): small models don't benefit from splits
  extensions     §6.6 ablations: serverless cold starts, QoS clustering
  accuracy       measured accuracy table (cached only on the xla backend)
  runtime-info   artifact load/compile statistics

run `dynasplit <cmd> --help` for per-command options.";

fn cmd_space() -> Result<()> {
    let mut t = Table::new(["network", "|X| raw", "|X| feasible", "gene bounds"]);
    for net in Network::ALL {
        let s = Space::new(net);
        t.row([
            net.name().to_string(),
            s.cardinality().to_string(),
            s.enumerate_feasible().len().to_string(),
            format!("{:?}", s.gene_bounds()),
        ]);
    }
    t.print();
    println!("\nTable 1 domains: CPU {:?} GHz; TPU {{off, std, max}}; GPU {{yes, no}}; \
              split 0..=L (VGG16 L=22, ViT L=19)", dynasplit::space::CPU_FREQS_GHZ);
    Ok(())
}

fn cmd_solve() -> Result<()> {
    let a = spec("solve", "offline phase: search the configuration space")
        .opt("net", "vgg16", "network (vgg16|vit)")
        .opt("trials", "193", "evaluation budget (trials)")
        .opt("strategy", "nsga3", "search strategy (nsga3|grid)")
        .opt_maybe("out", "output JSON path (default artifacts/pareto_<net>.json)")
        .parse_env(2)?;
    let net = Network::parse(a.str("net")?)?;
    let ctx = Ctx::load(a.str("artifacts")?);
    let mut solver = Solver::new(&ctx.testbed, net);
    solver.batch_per_trial = a.usize("batch")?;
    let strategy = match a.str("strategy")? {
        "nsga3" => Strategy::NsgaIII,
        "grid" => Strategy::Grid,
        other => bail!("unknown strategy {other:?}"),
    };
    let trials = a.usize("trials")?;
    println!(
        "[solve] {} via {:?}: {} trials x {} inferences (accuracy table: {})",
        net.name(), strategy, trials, solver.batch_per_trial, ctx.accuracy_origin
    );
    let sw = dynasplit::serve::Stopwatch::start();
    let out = solver.run(strategy, trials, a.u64("seed")?);
    println!(
        "[solve] {} trials in {:.2} s, non-dominated set size {}",
        out.trials.len(),
        sw.elapsed().as_secs_f64(),
        out.pareto.len()
    );
    let default_path = format!("{}/pareto_{}.json", a.str("artifacts")?, net.name());
    let path = a.get("out").unwrap_or(&default_path);
    std::fs::create_dir_all(std::path::Path::new(path).parent().unwrap()).ok();
    out.save(std::path::Path::new(path))?;
    println!("[solve] saved to {path}");
    let mut t = Table::new(["configuration", "latency", "energy", "accuracy"]);
    for p in &out.pareto {
        t.row([
            p.config.describe(),
            format!("{:.1} ms", p.latency_ms),
            format!("{:.2} J", p.energy_j),
            format!("{:.4}", p.accuracy),
        ]);
    }
    t.print();
    Ok(())
}

const STORE_HELP: &str = "dynasplit store — warm-restart persistence (DESIGN.md §17)

subcommands:
  export    solve (or load) Pareto fronts and write a versioned store document
  import    validate a store document and print what a restart would restore

a store document is self-describing JSON: schema + version + content digest,
plus per-network sections carrying the Pareto front, its (epoch, digest)
registry, placement-bucketed calibration, and windowed telemetry summaries.
`serve --store-in <doc>` boots from one with zero offline solves;
`serve --store-out <path>` writes one on clean shutdown.

run `dynasplit store export --help` / `dynasplit store import --help` for options.";

fn cmd_store() -> Result<()> {
    match std::env::args().nth(2).as_deref() {
        Some("export") => cmd_store_export(),
        Some("import") => cmd_store_import(),
        None | Some("help" | "--help" | "-h") => {
            println!("{STORE_HELP}");
            Ok(())
        }
        Some(other) => bail!("unknown store subcommand {other:?}\n\n{STORE_HELP}"),
    }
}

fn cmd_store_export() -> Result<()> {
    let a = spec("store export", "write a versioned warm-restart store document (§17)")
        .opt("net", "vgg16", "network (vgg16|vit; ignored with --mix)")
        .opt("trials", "60", "evaluation budget per solved front")
        .opt_maybe("pareto", "pareto JSON from `solve` (default: run a fresh search)")
        .opt_maybe("mix", "export every network of a mix, e.g. vgg16=0.7,vit=0.3")
        .opt_maybe("out", "output path (default artifacts/store_<net>.json)")
        .parse_env(3)?;
    let ctx = Ctx::load(a.str("artifacts")?);
    let seed = a.u64("seed")?;
    if a.get("pareto").is_some() && a.get("mix").is_some() {
        bail!("--pareto holds one network's front; --mix solves per network");
    }
    let nets = match a.get("mix") {
        Some(mix) => NetworkMix::parse(mix)?.networks(),
        None => vec![Network::parse(a.str("net")?)?],
    };
    let mut states = Vec::new();
    for net in &nets {
        let pareto = match a.get("pareto") {
            Some(path) => SolverOutput::load_pareto(std::path::Path::new(path))?,
            None => {
                let mut solver = Solver::new(&ctx.testbed, *net);
                solver.batch_per_trial = a.usize("batch")?;
                solver.run(Strategy::NsgaIII, a.usize("trials")?, seed).pareto
            }
        };
        let store = ConfigStore::new(ConfigSet::new(pareto));
        let state = NetworkState::capture(*net, &store);
        println!(
            "[store] {}: captured {} configs at epoch {}",
            net.name(),
            state.front.len(),
            state.epoch()
        );
        states.push(state);
    }
    let doc = StoreDocument::new(states);
    let default_path = if nets.len() > 1 {
        format!("{}/store_mix.json", a.str("artifacts")?)
    } else {
        format!("{}/store_{}.json", a.str("artifacts")?, nets[0].name())
    };
    let path = a.get("out").unwrap_or(&default_path);
    if let Some(parent) = std::path::Path::new(path).parent() {
        std::fs::create_dir_all(parent).ok();
    }
    doc.save(std::path::Path::new(path))?;
    println!(
        "[store] exported {} network(s), {} configs -> {path} (schema {} v{}, digest {:016x})",
        doc.networks.len(),
        doc.total_configs(),
        dynasplit::adapt::persist::SCHEMA,
        dynasplit::adapt::persist::SCHEMA_VERSION,
        doc.digest()
    );
    Ok(())
}

fn cmd_store_import() -> Result<()> {
    let a = spec("store import", "validate a store document; print what a restart restores")
        .opt_maybe("file", "store document path (required)")
        .parse_env(3)?;
    let path = match a.get("file") {
        Some(path) => path.clone(),
        None => bail!("store import needs --file <document>"),
    };
    let doc = StoreDocument::load(std::path::Path::new(&path))?;
    println!(
        "[store] {path}: schema {} v{}, digest {:016x}, {} network(s)",
        dynasplit::adapt::persist::SCHEMA,
        dynasplit::adapt::persist::SCHEMA_VERSION,
        doc.digest(),
        doc.networks.len()
    );
    for state in &doc.networks {
        let store = state.restore()?;
        let warm = &state.warm;
        let ewma = match warm.ewma {
            Some((value, count)) => format!("seeded ({value:.3} over {count} obs)"),
            None => "unseeded".to_string(),
        };
        println!(
            "[store]   {}: {} configs at epoch {} ({} registry entries); calibration \
             {} per-config ratio(s); telemetry {} row(s), ewma {}",
            state.net.name(),
            state.front.len(),
            store.epoch(),
            state.registry.len(),
            warm.calibration.observed_configs(),
            warm.rows.len(),
            ewma
        );
    }
    println!("[store] validated: content digest + registry + fronts all check out");
    Ok(())
}

fn cmd_serve() -> Result<()> {
    let a = spec("serve", "online phase: concurrent serving pipeline (simulated workload)")
        .opt("net", "vgg16", "network (vgg16|vit)")
        .opt("requests", "200", "number of requests")
        .opt("workers", "2", "serving workers (each owns an executor + config cache)")
        .opt("policy", "paper", "scheduling policy (paper|strict|budget|hysteresis)")
        .opt("budget", "20", "per-request energy cap in J (only --policy budget)")
        .opt("rate", "100", "mean arrival rate (requests/s)")
        .opt("burst", "0", "burst size (0 = pure Poisson arrivals)")
        .opt("queue", "256", "admission queue capacity (per shard)")
        .opt("shards", "1", "admission queue shards (1 = the classic single queue)")
        .opt("coalesce", "4", "max same-config requests coalesced per activation")
        .opt(
            "time-scale",
            "0",
            "0 = inject as fast as possible; >0 = real-time replay, wall-clock per \
             experiment ms (1 = real time, 2 = half speed, 0.5 = double speed; \
             wait-aware: budgets shrink with queue wait, expired requests shed)",
        )
        .flag("no-reuse", "disable the config-reuse cache (reconfigure every batch)")
        .flag(
            "discrete",
            "discrete-event clock: batch completions advance simulated time, the run \
             replays at full speed with real-time queueing/expiry semantics (DESIGN.md §14)",
        )
        .flag(
            "adapt",
            "close the loop: record telemetry, detect drift, re-solve online, hot-swap \
             the Pareto store under traffic (and, in real-time mode, apply EWMA \
             admission backpressure)",
        )
        .opt("adapt-window", "32", "telemetry samples per drift window (--adapt)")
        .opt(
            "adapt-threshold",
            "0.25",
            "relative measured-vs-predicted error that counts as drift (--adapt)",
        )
        .opt("adapt-k", "2", "consecutive off-model windows before a re-solve (--adapt)")
        .opt("adapt-trials", "96", "evaluation budget of the online re-solve (--adapt)")
        .opt_maybe("trace", "record a flight-recorder trace to this path (Chrome trace JSON)")
        .opt_maybe("metrics", "write Prometheus-style metrics exposition text to this path")
        .opt_maybe("report-json", "write the full serve report as JSON to this path")
        .opt_maybe("pareto", "pareto JSON from `solve` (default: run a fresh 20% search)")
        .opt_maybe(
            "mix",
            "serve a network mix from one pipeline, e.g. vgg16=0.7,vit=0.3 \
             (per-network Pareto stores; ignores --net)",
        )
        .opt_maybe(
            "store-in",
            "boot from a `store export` document: restore fronts + epoch registry + \
             warm state, skipping the offline solve entirely (DESIGN.md §17)",
        )
        .opt_maybe(
            "store-out",
            "export the store (and, with --adapt, the loop's warm state) to this \
             path on clean shutdown",
        )
        .parse_env(2)?;
    let ctx = Ctx::load(a.str("artifacts")?);
    let seed = a.u64("seed")?;
    if let Some(mix) = a.get("mix") {
        let mix = NetworkMix::parse(mix)?;
        return serve_mixed(&a, &ctx, seed, &mix);
    }
    let net = Network::parse(a.str("net")?)?;
    if a.get("pareto").is_some() && a.get("store-in").is_some() {
        bail!("--pareto and --store-in both name a front source; pick one");
    }
    // warm-restart seam (DESIGN.md §17): an imported document replaces
    // the offline solve entirely — fronts, epoch registry, and the
    // adaptation loop's warm state all come from the previous process
    let (store, store_source, warm_in) = match a.get("store-in") {
        Some(path) => {
            let doc = StoreDocument::load(std::path::Path::new(path))?;
            let digest = format!("{:016x}", doc.digest());
            let state = doc
                .state(net)
                .ok_or_else(|| anyhow::anyhow!("{path} has no {} section", net.name()))?;
            let store = state.restore()?;
            println!(
                "[serve] store: imported {} configs at epoch {} from {path} \
                 (digest {digest}; zero offline solves)",
                state.front.len(),
                store.epoch(),
            );
            (store, StoreSource::Imported { doc_digest: digest }, state.warm.clone())
        }
        None => {
            let pareto = match a.get("pareto") {
                Some(path) => SolverOutput::load_pareto(std::path::Path::new(path))?,
                None => {
                    let mut solver = Solver::new(&ctx.testbed, net);
                    solver.batch_per_trial = a.usize("batch")?;
                    solver.run(Strategy::NsgaIII, solver.trials_for_fraction(0.2), seed).pareto
                }
            };
            let sw = dynasplit::serve::Stopwatch::start();
            let set = ConfigSet::new(pareto);
            println!(
                "[serve] startup: sorted + indexed {} configs in {:.3} ms",
                set.len(),
                sw.elapsed_ms()
            );
            (ConfigStore::new(set), StoreSource::Solved, WarmState::identity())
        }
    };
    let policy = parse_policy(&a, &[net])?;
    let gen = WorkloadGen::paper(net);
    let mut rng = Pcg32::new(seed, 91);
    let process = arrival_process(&a)?;
    let tl = dynasplit::workload::timeline(&gen, &process, a.usize("requests")?, &mut rng);
    let cfg = PipelineConfig {
        workers: a.usize("workers")?,
        queue_capacity: a.usize("queue")?,
        max_batch: a.usize("coalesce")?,
        time_scale: a.f64("time-scale")?,
        seed,
        reuse: !a.flag("no-reuse"),
        shards: a.usize("shards")?,
        discrete: a.flag("discrete"),
    };
    let recorder = serve_recorder(&a, &cfg);
    let mut warm_out = WarmState::identity();
    let mut report = if a.flag("adapt") {
        let adapt_cfg = AdaptConfig {
            window: a.usize("adapt-window")?,
            drift: DriftConfig {
                rel_threshold: a.f64("adapt-threshold")?,
                consecutive_windows: a.usize("adapt-k")?,
                ..DriftConfig::default()
            },
            resolve: ResolveConfig { trials: a.usize("adapt-trials")?, seed, ..Default::default() },
            ..AdaptConfig::default()
        };
        let telemetry = Telemetry::new(cfg.workers, adapt_cfg.telemetry_capacity);
        let mut control = AdaptiveLoop::new(&store, &telemetry, &ctx.testbed, net, adapt_cfg)
            .with_recorder(&recorder);
        if warm_in.is_warm() {
            control.warm_start(&warm_in.samples(), warm_in.ewma);
            println!(
                "[serve] store: warm-started calibration from {} summary row(s)",
                warm_in.rows.len()
            );
        }
        let closed = run_closed_loop(control, policy.as_ref(), &tl, &cfg, |_| {
            Ok(PerRequestSimExecutor { testbed: &ctx.testbed, stream: 92 })
        })?;
        let s = closed.adapt;
        println!(
            "[serve] adaptation: {} samples, {} windows, {} drift events, {} re-solves, \
             {} hot-swaps ({} store epochs)",
            s.samples,
            s.windows,
            s.drift_events,
            s.resolves,
            s.swaps,
            closed.epochs.len()
        );
        warm_out = closed.warm;
        closed.serve
    } else {
        // equivalent to `run_pipeline` (broadcast store, no retry, no
        // breakers) with the flight recorder threaded through
        let stores = StoreMap::broadcast(&store);
        run_pipeline_resilient(
            &stores,
            policy.as_ref(),
            &tl,
            &cfg,
            None,
            None,
            RetryPolicy::none(),
            None,
            &recorder,
            |_| Ok(PerRequestSimExecutor { testbed: &ctx.testbed, stream: 92 }),
        )?
    };
    report.store_source = store_source;
    println!("[serve] {} — {}", policy.name(), report.summary_line());
    write_serve_artifacts(&a, &recorder, &report)?;
    if let Some(path) = a.get("store-out") {
        let doc = StoreDocument::single(NetworkState::capture(net, &store).with_warm(warm_out));
        if let Some(parent) = std::path::Path::new(path).parent() {
            std::fs::create_dir_all(parent).ok();
        }
        doc.save(std::path::Path::new(path))?;
        println!(
            "[serve] store: exported {} configs at epoch {} -> {path} (digest {:016x})",
            doc.total_configs(),
            store.epoch(),
            doc.digest()
        );
    }
    let metrics = report.to_metric_set("dynasplit");
    if !metrics.is_empty() {
        let (c, s, e) = metrics.placement_counts();
        println!(
            "[serve] completed placement: {c} cloud / {s} split / {e} edge; \
             median latency {:.0} ms; median energy {:.1} J",
            metrics.latency_summary().median,
            metrics.energy_summary().median
        );
    }
    dynasplit::report::write_csv(
        a.str("artifacts")?,
        &format!("serve_{}", net.name()),
        &dynasplit::report::metric_set_table(&metrics),
    )?;
    Ok(())
}

/// Scheduling policy shared by `serve` and `serve --mix`.  `nets` are
/// the networks the policy will schedule for: a `hysteresis` policy
/// buckets QoS over the union of their Table-2 latency bounds, and the
/// pipeline forks it per (worker, network) lane (`PolicySet`) so its
/// sticky state never thrashes across networks under `--mix`.
fn parse_policy(a: &Args, nets: &[Network]) -> Result<Box<dyn SchedulingPolicy>> {
    Ok(match a.str("policy")? {
        "paper" => Box::new(PaperPolicy),
        "strict" => Box::new(StrictDeadlinePolicy),
        "budget" => Box::new(EnergyBudgetPolicy { budget_j: a.f64("budget")? }),
        "hysteresis" => {
            let mut min_ms = f64::INFINITY;
            let mut max_ms = f64::NEG_INFINITY;
            for &net in nets {
                let b = LatencyBounds::paper(net);
                min_ms = min_ms.min(b.min_ms);
                max_ms = max_ms.max(b.max_ms);
            }
            Box::new(HysteresisPolicy::new(6, min_ms, max_ms, 3.0))
        }
        other => bail!("unknown policy {other:?} (expected paper|strict|budget|hysteresis)"),
    })
}

/// Arrival process from the shared `--rate`/`--burst` serve flags.
fn arrival_process(a: &Args) -> Result<ArrivalProcess> {
    Ok(match a.usize("burst")? {
        0 => ArrivalProcess::Poisson { rate_per_s: a.f64("rate")? },
        burst_size => ArrivalProcess::Bursty {
            base_rate_per_s: a.f64("rate")?,
            period_s: 1.0,
            burst_size,
        },
    })
}

/// Flight recorder for `serve`: live when `--trace` or `--metrics`
/// asks for an artifact, the single-branch no-op otherwise (so plain
/// runs stay bitwise-identical to an unwired pipeline, DESIGN.md §16).
fn serve_recorder(a: &Args, cfg: &PipelineConfig) -> Recorder {
    if a.get("trace").is_some() || a.get("metrics").is_some() {
        Recorder::flight(cfg.workers, cfg.shards, FlightRecorder::DEFAULT_CAPACITY)
    } else {
        Recorder::Off
    }
}

/// Write the `--trace` / `--metrics` / `--report-json` serve artifacts.
fn write_serve_artifacts(a: &Args, recorder: &Recorder, report: &ServeReport) -> Result<()> {
    let trace = recorder.take();
    if let Some(path) = a.get("trace") {
        let trace = trace.as_ref().expect("recorder is live whenever --trace is given");
        std::fs::write(path, chrome::chrome_trace(trace).encode())?;
        println!(
            "[serve] trace: {} events, {} spans ({} dropped) -> {path} \
             (open in chrome://tracing or Perfetto)",
            trace.len(),
            trace.spans().len(),
            trace.dropped
        );
    }
    if let Some(path) = a.get("metrics") {
        std::fs::write(path, expose::exposition(report, trace.as_ref()))?;
        println!("[serve] metrics exposition -> {path}");
    }
    if let Some(path) = a.get("report-json") {
        std::fs::write(path, report.to_json().encode())?;
        println!("[serve] report json -> {path}");
    }
    Ok(())
}

/// `dynasplit trace --file out.json`: replay a recorded trace into a
/// per-request waterfall and a span-stat table (DESIGN.md §16).
fn cmd_trace() -> Result<()> {
    let a = ArgSpec::new(
        "dynasplit trace".to_string(),
        "replay a recorded flight-recorder trace (from `serve --trace`)",
    )
    .opt_maybe("file", "trace JSON written by `serve --trace` (required)")
    .opt("top", "25", "request spans shown in the waterfall")
    .parse_env(2)?;
    let path = a.str("file")?;
    let doc = Json::parse_file(std::path::Path::new(path))?;
    let trace = chrome::parse_trace(&doc)?;
    println!(
        "[trace] {path}: {} events across {} lanes ({} workers, {} shards, 1 control; \
         {} dropped)",
        trace.len(),
        trace.lanes.len(),
        trace.workers,
        trace.shards,
        trace.dropped
    );

    let spans = trace.spans();
    // the waterfall scale spans the stamped events; virtual-clock
    // traces carry no timestamps and fall back to the lifecycle path
    let bounds: Vec<(f64, f64)> = spans.iter().filter_map(|s| s.bounds_ms()).collect();
    let t0 = bounds.iter().map(|b| b.0).fold(f64::INFINITY, f64::min);
    let t1 = bounds.iter().map(|b| b.1).fold(f64::NEG_INFINITY, f64::max);
    let top = a.usize("top")?;
    let mut t = Table::new(["request", "shard", "worker", "attempts", "terminal", "span"]);
    for s in spans.iter().take(top) {
        let cell = |v: Option<usize>| v.map_or("-".to_string(), |x| x.to_string());
        let span_cell = match s.bounds_ms() {
            Some((first, last)) => format!(
                "{:>7.1}..{:<7.1} |{}|",
                first,
                last,
                waterfall_bar(first, last, t0, t1, 32)
            ),
            None => {
                let names: Vec<&str> = s.events.iter().map(|e| e.kind.name()).collect();
                names.join(" > ")
            }
        };
        t.row([
            s.id.to_string(),
            cell(s.shard()),
            cell(s.worker()),
            s.attempts().to_string(),
            s.terminal().map_or("-", |e| e.kind.name()).to_string(),
            span_cell,
        ]);
    }
    t.print();
    if spans.len() > top {
        println!("[trace] ... {} more spans (raise --top to see them)", spans.len() - top);
    }

    let c = trace.span_counts();
    let mut t = Table::new(["outcome", "spans"]);
    for (name, n) in [
        ("admitted", c.admitted),
        ("done", c.done),
        ("done, retried", c.retried),
        ("done, degraded", c.degraded_served),
        ("failed_retry", c.failed_retry),
        ("exec_failed", c.exec_failed),
        ("rejected_policy", c.rejected_policy),
        ("rejected_full", c.rejected_full),
        ("shed", c.shed),
        ("expired", c.expired),
        ("unknown_net", c.unknown_net),
        ("terminal total", c.terminals()),
    ] {
        t.row([name.to_string(), n.to_string()]);
    }
    t.print();

    let control = trace.control_events();
    if !control.is_empty() {
        println!("\n[trace] control plane ({} events):", control.len());
        for ev in control {
            match ev.at_ms {
                Some(at) => println!("  @{at:>10.1} ms  {:?}", ev.kind),
                None => println!("  @       -     {:?}", ev.kind),
            }
        }
    }
    println!("\n[trace] digest {:016x}", trace.digest());
    Ok(())
}

/// Fixed-width `#` bar spanning `[first, last]` on a `[t0, t1]` scale.
fn waterfall_bar(first: f64, last: f64, t0: f64, t1: f64, width: usize) -> String {
    let scale = (t1 - t0).max(f64::EPSILON);
    let start = (((first - t0) / scale) * width as f64).floor() as usize;
    let end = ((((last - t0) / scale) * width as f64).ceil() as usize).clamp(start + 1, width);
    (0..width).map(|i| if i >= start.min(width - 1) && i < end { '#' } else { '.' }).collect()
}

/// `dynasplit serve --mix …`: one pipeline, per-network Pareto stores,
/// an interleaved workload (DESIGN.md §12).
fn serve_mixed(a: &Args, ctx: &Ctx, seed: u64, mix: &NetworkMix) -> Result<()> {
    if a.flag("adapt") {
        bail!(
            "--adapt is single-network for now (concurrent per-network adaptation \
             loops need a telemetry demux — ROADMAP follow-on); drop --mix or --adapt"
        );
    }
    if a.get("pareto").is_some() {
        bail!("--pareto holds one network's front; --mix runs a fresh 20% search per network");
    }
    let policy = parse_policy(a, &mix.networks())?;
    // offline phase: one 20%-budget search per mixed network — each
    // network gets its own independently hot-swappable store.  With
    // --store-in the per-network sections of one §17 document replace
    // every solve: documents compose under --mix via StoreMap.
    let mut fronts = Vec::new();
    let store_source = match a.get("store-in") {
        Some(path) => {
            let doc = StoreDocument::load(std::path::Path::new(path))?;
            let digest = format!("{:016x}", doc.digest());
            for net in mix.networks() {
                let state = doc
                    .state(net)
                    .ok_or_else(|| anyhow::anyhow!("{path} has no {} section", net.name()))?;
                let store = state.restore()?;
                println!(
                    "[serve] {}: imported {} configs at epoch {} ({:.0}% of traffic; \
                     zero offline solves)",
                    net.name(),
                    state.front.len(),
                    store.epoch(),
                    mix.share(net) * 100.0
                );
                fronts.push((net, store));
            }
            StoreSource::Imported { doc_digest: digest }
        }
        None => {
            for net in mix.networks() {
                let mut solver = Solver::new(&ctx.testbed, net);
                solver.batch_per_trial = a.usize("batch")?;
                let sw = dynasplit::serve::Stopwatch::start();
                let pareto =
                    solver.run(Strategy::NsgaIII, solver.trials_for_fraction(0.2), seed).pareto;
                let set = ConfigSet::new(pareto);
                println!(
                    "[serve] {}: sorted + indexed {} configs in {:.3} ms ({:.0}% of traffic)",
                    net.name(),
                    set.len(),
                    sw.elapsed_ms(),
                    mix.share(net) * 100.0
                );
                fronts.push((net, ConfigStore::new(set)));
            }
            StoreSource::Solved
        }
    };
    let mut stores = StoreMap::new();
    for (net, store) in &fronts {
        stores.insert(*net, store);
    }
    let mut rng = Pcg32::new(seed, 91);
    let process = arrival_process(a)?;
    let tl = mixed_timeline(mix, WorkloadGen::paper, &process, a.usize("requests")?, &mut rng);
    let cfg = PipelineConfig {
        workers: a.usize("workers")?,
        queue_capacity: a.usize("queue")?,
        max_batch: a.usize("coalesce")?,
        time_scale: a.f64("time-scale")?,
        seed,
        reuse: !a.flag("no-reuse"),
        shards: a.usize("shards")?,
        discrete: a.flag("discrete"),
    };
    let recorder = serve_recorder(a, &cfg);
    let mut report = run_pipeline_resilient(
        &stores,
        policy.as_ref(),
        &tl,
        &cfg,
        None,
        None,
        RetryPolicy::none(),
        None,
        &recorder,
        |_| Ok(PerRequestSimExecutor { testbed: &ctx.testbed, stream: 92 }),
    )?;
    report.store_source = store_source;
    println!("[serve] {} — {}", policy.name(), report.summary_line());
    write_serve_artifacts(a, &recorder, &report)?;
    if let Some(path) = a.get("store-out") {
        let doc = StoreDocument::new(
            fronts.iter().map(|(net, store)| NetworkState::capture(*net, store)).collect(),
        );
        if let Some(parent) = std::path::Path::new(path).parent() {
            std::fs::create_dir_all(parent).ok();
        }
        doc.save(std::path::Path::new(path))?;
        println!(
            "[serve] store: exported {} network(s), {} configs -> {path} (digest {:016x})",
            doc.networks.len(),
            doc.total_configs(),
            doc.digest()
        );
    }
    for b in report.breakdown() {
        println!(
            "[serve]   {:>6}: {}/{} done; QoS hit {:.0}%; {:.2} J/req; store epochs {:?}",
            b.net.name(),
            b.done,
            b.requests,
            b.qos_hit_rate() * 100.0,
            b.mean_energy_j(),
            report.epochs_observed_for(b.net),
        );
        let metrics = report.to_metric_set_for(b.net, "dynasplit");
        dynasplit::report::write_csv(
            a.str("artifacts")?,
            &format!("serve_mixed_{}", b.net.name()),
            &dynasplit::report::metric_set_table(&metrics),
        )?;
    }
    Ok(())
}

fn cmd_mixed() -> Result<()> {
    let a = spec("mixed", "mixed-network serving experiment (vgg16 + vit, one pipeline)")
        .opt("requests", "240", "requests per pipeline run")
        .parse_env(2)?;
    let ctx = Ctx::load(a.str("artifacts")?);
    let exp = experiments::mixed::run(&ctx, a.usize("requests")?, a.u64("seed")?);
    experiments::mixed::print_report(&exp);
    Ok(())
}

fn cmd_adapt() -> Result<()> {
    let a = spec("adapt", "closed-loop adaptation experiment (mid-run world shift)")
        .opt("net", "vgg16", "network (vgg16|vit)")
        .opt("requests", "360", "requests per run (the world steps a third in)")
        .parse_env(2)?;
    let net = Network::parse(a.str("net")?)?;
    let ctx = Ctx::load(a.str("artifacts")?);
    let exp = experiments::adaptation::run(&ctx, net, a.usize("requests")?, a.u64("seed")?);
    experiments::adaptation::print_report(&exp);
    Ok(())
}

fn cmd_throughput() -> Result<()> {
    let a = spec("throughput", "serving-pipeline throughput experiment")
        .opt("net", "vgg16", "network (vgg16|vit)")
        .opt("requests", "400", "requests per pipeline run")
        .parse_env(2)?;
    let net = Network::parse(a.str("net")?)?;
    let ctx = Ctx::load(a.str("artifacts")?);
    let exp = experiments::serving::run(&ctx, net, a.usize("requests")?, a.u64("seed")?);
    experiments::serving::print_report(&exp);
    Ok(())
}

fn cmd_scale() -> Result<()> {
    let a = spec("scale", "fleet-scale sweep: sharded admission under a discrete-event clock")
        .opt("requests", "100000", "fleet requests per sweep cell")
        .opt("devices", "5000", "devices in the simulated fleet")
        .parse_env(2)?;
    let ctx = Ctx::load(a.str("artifacts")?);
    let exp = experiments::scale::run(
        &ctx,
        a.usize("requests")?,
        a.usize("devices")?,
        a.u64("seed")?,
    );
    experiments::scale::print_report(&exp);
    Ok(())
}

fn cmd_chaos() -> Result<()> {
    let a = spec("chaos", "chaos serving: fault scenarios x recovery modes")
        .opt("requests", "240", "requests per cell")
        .parse_env(2)?;
    let exp = experiments::chaos::run(a.usize("requests")?, a.u64("seed")?);
    experiments::chaos::print_report(&exp);
    Ok(())
}

fn cmd_prelim() -> Result<()> {
    let a = spec("prelim", "Fig. 2 preliminary study").parse_env(2)?;
    let ctx = Ctx::load(a.str("artifacts")?);
    println!("[prelim] accuracy table: {}", ctx.accuracy_origin);
    let r = experiments::prelim::run(&ctx, a.usize("batch")?, a.u64("seed")?);
    experiments::prelim::print_report(&r);
    Ok(())
}

fn cmd_bounds() -> Result<()> {
    let a = spec("bounds", "Table 2 latency bounds").parse_env(2)?;
    let ctx = Ctx::load(a.str("artifacts")?);
    let batch = a.usize("batch")?.min(200); // full-space sweep: keep trials lean
    let vgg = experiments::bounds::run(&ctx, Network::Vgg16, batch, a.u64("seed")?);
    let vit = experiments::bounds::run(&ctx, Network::Vit, batch, a.u64("seed")?);
    experiments::bounds::print_report(&vgg, &vit);
    Ok(())
}

fn cmd_workload() -> Result<()> {
    let a = spec("workload", "Fig. 5 QoS distributions")
        .opt("requests", "10000", "draws per network")
        .parse_env(2)?;
    let n = a.usize("requests")?;
    let dists = [
        experiments::workload_dist::run(Network::Vgg16, n, a.u64("seed")?),
        experiments::workload_dist::run(Network::Vit, n, a.u64("seed")?),
    ];
    experiments::workload_dist::print_report(&dists);
    Ok(())
}

fn cmd_testbed() -> Result<()> {
    let a = spec("testbed", "Fig. 6-9 testbed experiment")
        .opt("requests", "50", "requests per network")
        .parse_env(2)?;
    let ctx = Ctx::load(a.str("artifacts")?);
    println!("[testbed] accuracy table: {}", ctx.accuracy_origin);
    for net in Network::ALL {
        let exp = experiments::testbed_exp::run(
            &ctx,
            net,
            a.usize("requests")?,
            a.usize("batch")?,
            a.u64("seed")?,
        );
        experiments::testbed_exp::print_report(&exp);
        for m in exp.strategies.all() {
            dynasplit::report::write_csv(
                a.str("artifacts")?,
                &format!("testbed_{}_{}", net.name(), m.strategy),
                &dynasplit::report::metric_set_table(m),
            )?;
        }
    }
    Ok(())
}

fn cmd_ablation() -> Result<()> {
    let a = spec("ablation", "Fig. 10 search-budget ablation")
        .opt("requests", "50", "requests")
        .parse_env(2)?;
    let ctx = Ctx::load(a.str("artifacts")?);
    let r = experiments::ablation::run(&ctx, a.usize("requests")?, a.usize("batch")?, a.u64("seed")?);
    experiments::ablation::print_report(&r);
    Ok(())
}

fn cmd_simulate() -> Result<()> {
    let a = spec("simulate", "Fig. 11-14 simulation experiment")
        .opt("requests", "10000", "requests per network")
        .parse_env(2)?;
    let ctx = Ctx::load(a.str("artifacts")?);
    println!("[simulate] accuracy table: {}", ctx.accuracy_origin);
    for net in Network::ALL {
        let exp = experiments::simulation::run(
            &ctx,
            net,
            a.usize("requests")?,
            a.usize("batch")?,
            a.u64("seed")?,
        );
        experiments::simulation::print_report(&exp);
    }
    Ok(())
}

fn cmd_overhead() -> Result<()> {
    let a = spec("overhead", "Fig. 15 controller overhead")
        .opt("requests", "50", "requests per network")
        .parse_env(2)?;
    let ctx = Ctx::load(a.str("artifacts")?);
    let (requests, batch, seed) = (a.usize("requests")?, a.usize("batch")?, a.u64("seed")?);
    let results: Vec<_> = Network::ALL
        .iter()
        .map(|&net| experiments::overhead::run(&ctx, net, requests, batch, seed))
        .collect();
    experiments::overhead::print_report(&results);
    Ok(())
}

fn cmd_smallmodels() -> Result<()> {
    let profiles = experiments::small_models::run();
    experiments::small_models::print_report(&profiles);
    Ok(())
}

fn cmd_extensions() -> Result<()> {
    let a = spec("extensions", "§6.6 ablations")
        .opt("requests", "50", "requests per ablation")
        .opt("coldstart", "800", "cold-start penalty (ms)")
        .opt("buckets", "6", "QoS clustering buckets")
        .parse_env(2)?;
    let ctx = Ctx::load(a.str("artifacts")?);
    let cold = experiments::extensions::run_cold_start(
        &ctx, a.usize("requests")?, a.f64("coldstart")?, a.u64("seed")?);
    experiments::extensions::print_cold_start(&cold);
    let cl = experiments::extensions::run_clustering(
        &ctx, a.usize("requests")?, a.usize("buckets")?, a.u64("seed")?);
    experiments::extensions::print_clustering(&cl);
    Ok(())
}

fn cmd_accuracy() -> Result<()> {
    let a = spec("accuracy", "measured accuracy table").parse_env(2)?;
    let manifest = Manifest::load(a.str("artifacts")?)?;
    let backend = dynasplit::runtime::default_backend()?;
    println!("[accuracy] backend: {} ({})", backend.name(), backend.platform());
    // Only the XLA backend runs the real networks: the reference
    // interpreter's synthetic weights make the table meaningless, and
    // its scalar loops make the O(L²) prefix sweep over the eval set
    // take hours — refuse instead of hanging, and never poison the
    // measured cache that `Ctx::load` prefers over the manifest.
    if backend.name() != "xla" {
        bail!(
            "`dynasplit accuracy` needs the real XLA backend (build with --features xla); \
             the {} backend has synthetic weights and cannot produce a fidelity-grade table",
            backend.name()
        );
    }
    let vgg = dynasplit::runtime::NetworkRuntime::load(backend.as_ref(), &manifest, Network::Vgg16)?;
    let vit = dynasplit::runtime::NetworkRuntime::load(backend.as_ref(), &manifest, Network::Vit)?;
    println!(
        "[accuracy] runtimes loaded: vgg {:.0} ms, vit {:.0} ms",
        vgg.load_ms, vit.load_ms
    );
    let sw = dynasplit::serve::Stopwatch::start();
    let measured = dynasplit::runtime::evaluate::measure_cached(&manifest, &vgg, &vit, true)?;
    println!("[accuracy] measured in {:.1} s", sw.elapsed().as_secs_f64());
    // cross-check against the python oracle expectations
    let exp = &manifest.vgg16.expected_accuracy;
    println!(
        "vgg16 fp32: measured {:.4} vs python-oracle {:.4}",
        measured.vgg_fp32, exp.fp32
    );
    println!(
        "vit   fp32: measured {:.4} vs python-oracle {:.4}",
        measured.vit_fp32, manifest.vit.expected_accuracy.fp32
    );
    Ok(())
}

fn cmd_runtime_info() -> Result<()> {
    let a = spec("runtime-info", "artifact load/compile statistics").parse_env(2)?;
    let manifest = Manifest::load(a.str("artifacts")?)?;
    let backend = dynasplit::runtime::default_backend()?;
    println!("backend: {} ({})", backend.name(), backend.platform());
    let mut t = Table::new(["network", "layers", "int8 variants", "load+compile"]);
    for net in Network::ALL {
        let rt = dynasplit::runtime::NetworkRuntime::load(backend.as_ref(), &manifest, net)?;
        let entry = manifest.network(net);
        t.row([
            net.name().to_string(),
            rt.num_layers().to_string(),
            entry.layers.iter().filter(|l| l.int8.is_some()).count().to_string(),
            format!("{:.0} ms", rt.load_ms),
        ]);
    }
    t.print();
    Ok(())
}
