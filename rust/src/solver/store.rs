//! Persistence for the offline phase: trial logs, the non-dominated set
//! (the artifact the Controller loads at startup), and the observation
//! pool the Simulation Experiment samples from.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{Context, Result};

use super::Strategy;
use crate::simulator::TrialResult;
use crate::space::{feasible, Config, Network, TpuMode};
use crate::util::json::Json;
use crate::util::rng::Pcg32;

/// One non-dominated configuration with its measured objective values —
/// what the paper's Solver hands to the Controller.
#[derive(Debug, Clone, PartialEq)]
pub struct ParetoEntry {
    pub config: Config,
    pub latency_ms: f64,
    pub energy_j: f64,
    pub accuracy: f64,
}

/// Complete offline-phase output.
#[derive(Debug, Clone)]
pub struct SolverOutput {
    pub net: Network,
    pub strategy: Strategy,
    pub seed: u64,
    pub trials: Vec<TrialResult>,
    pub pareto: Vec<ParetoEntry>,
}

fn config_to_json(c: &Config) -> Json {
    Json::obj(vec![
        ("net", Json::str(c.net.name())),
        ("cpu_idx", Json::num(c.cpu_idx as f64)),
        ("tpu", Json::str(c.tpu.label())),
        ("gpu", Json::Bool(c.gpu)),
        ("split", Json::num(c.split as f64)),
    ])
}

fn config_from_json(v: &Json) -> Result<Config> {
    let net = Network::parse(v.get("net")?.as_str()?)?;
    let tpu = match v.get("tpu")?.as_str()? {
        "off" => TpuMode::Off,
        "std" => TpuMode::Std,
        "max" => TpuMode::Max,
        other => anyhow::bail!("bad tpu mode {other:?}"),
    };
    let c = Config {
        net,
        cpu_idx: v.get("cpu_idx")?.as_usize()?,
        tpu,
        gpu: v.get("gpu")?.as_bool()?,
        split: v.get("split")?.as_usize()?,
    };
    anyhow::ensure!(c.cpu_idx < crate::space::CPU_FREQS_GHZ.len(), "cpu_idx out of range");
    anyhow::ensure!(c.split <= net.num_layers(), "split out of range");
    anyhow::ensure!(feasible::is_feasible(&c), "infeasible persisted config {c:?}");
    Ok(c)
}

fn entry_to_json(e: &ParetoEntry) -> Json {
    Json::obj(vec![
        ("config", config_to_json(&e.config)),
        ("latency_ms", Json::num(e.latency_ms)),
        ("energy_j", Json::num(e.energy_j)),
        ("accuracy", Json::num(e.accuracy)),
    ])
}

fn entry_from_json(v: &Json) -> Result<ParetoEntry> {
    Ok(ParetoEntry {
        config: config_from_json(v.get("config")?)?,
        latency_ms: v.get("latency_ms")?.as_f64()?,
        energy_j: v.get("energy_j")?.as_f64()?,
        accuracy: v.get("accuracy")?.as_f64()?,
    })
}

impl SolverOutput {
    /// Persist the non-dominated set + a compact trial log.
    pub fn save(&self, path: &Path) -> Result<()> {
        let trials = Json::arr(self.trials.iter().map(|t| {
            Json::obj(vec![
                ("config", config_to_json(&t.config)),
                ("latency_ms", Json::num(t.latency_ms)),
                ("energy_j", Json::num(t.energy_j)),
                ("edge_energy_j", Json::num(t.edge_energy_j)),
                ("cloud_energy_j", Json::num(t.cloud_energy_j)),
                ("accuracy", Json::num(t.accuracy)),
            ])
        }));
        let root = Json::obj(vec![
            ("net", Json::str(self.net.name())),
            (
                "strategy",
                Json::str(match self.strategy {
                    Strategy::NsgaIII => "nsga3",
                    Strategy::Grid => "grid",
                }),
            ),
            ("seed", Json::num(self.seed as f64)),
            ("pareto", Json::arr(self.pareto.iter().map(entry_to_json))),
            ("trials", trials),
        ]);
        std::fs::write(path, root.encode()).with_context(|| format!("writing {}", path.display()))
    }

    /// Load only the non-dominated set (what the Controller needs).
    pub fn load_pareto(path: &Path) -> Result<Vec<ParetoEntry>> {
        let root = Json::parse_file(path)?;
        root.get("pareto")?.as_arr()?.iter().map(entry_from_json).collect()
    }
}

/// Pool of repeated observations per configuration — the Simulation
/// Experiment's data source (§6.2: each simulated request re-samples a
/// stored observation of its selected configuration, ≥ 5 per config)
/// and the measured-truth source of the online re-solve.  Keyed by the
/// whole [`Config`] (including the network), so observations of two
/// networks sharing hardware settings can never pool together.
#[derive(Debug, Clone, Default)]
pub struct ObservationPool {
    by_config: BTreeMap<Config, Vec<Observation>>,
}

/// One stored observation of a configuration.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Observation {
    pub latency_ms: f64,
    pub energy_j: f64,
    pub edge_energy_j: f64,
    pub cloud_energy_j: f64,
    pub accuracy: f64,
}

impl ObservationPool {
    /// Record an observation from a trial.
    pub fn record(&mut self, t: &TrialResult) {
        self.record_observation(
            &t.config,
            Observation {
                latency_ms: t.latency_ms,
                energy_j: t.energy_j,
                edge_energy_j: t.edge_energy_j,
                cloud_energy_j: t.cloud_energy_j,
                accuracy: t.accuracy,
            },
        );
    }

    /// Record a raw observation for `config` — the seam the adaptation
    /// loop uses to pool *served-request* measurements so the online
    /// re-solve can score observed configurations by measured truth.
    pub fn record_observation(&mut self, config: &Config, obs: Observation) {
        self.by_config.entry(*config).or_default().push(obs);
    }

    pub fn observations(&self, c: &Config) -> &[Observation] {
        self.by_config.get(c).map(|v| v.as_slice()).unwrap_or(&[])
    }

    pub fn min_observations(&self) -> usize {
        self.by_config.values().map(|v| v.len()).min().unwrap_or(0)
    }

    /// Ensure every listed configuration has ≥ `min` observations by
    /// running additional trials on `testbed` (the paper's §6.2 setup).
    pub fn ensure_coverage(
        &mut self,
        configs: &[Config],
        min: usize,
        testbed: &crate::simulator::Testbed,
        batch: usize,
        rng: &mut Pcg32,
    ) {
        for c in configs {
            while self.observations(c).len() < min {
                let t = testbed.run_trial_n(c, batch, rng);
                self.record(&t);
            }
        }
    }

    /// Sample a stored observation for `config` uniformly at random.
    pub fn sample(&self, config: &Config, rng: &mut Pcg32) -> Option<Observation> {
        let obs = self.observations(config);
        (!obs.is_empty()).then(|| *rng.choose(obs))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simulator::Testbed;
    use crate::solver::{Solver, Strategy};

    fn tmpfile(tag: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("dynasplit_store_{tag}_{}.json", std::process::id()))
    }

    fn small_output() -> SolverOutput {
        let mut tb = Testbed::synthetic();
        tb.batch_per_trial = 30;
        let mut s = Solver::new(&tb, Network::Vgg16);
        s.batch_per_trial = 30;
        s.run(Strategy::NsgaIII, 60, 5)
    }

    #[test]
    fn save_load_roundtrip() {
        let out = small_output();
        let path = tmpfile("roundtrip");
        out.save(&path).unwrap();
        let loaded = SolverOutput::load_pareto(&path).unwrap();
        assert_eq!(loaded.len(), out.pareto.len());
        for (a, b) in loaded.iter().zip(&out.pareto) {
            assert_eq!(a.config, b.config);
            assert!((a.latency_ms - b.latency_ms).abs() < 1e-9);
            assert!((a.accuracy - b.accuracy).abs() < 1e-9);
        }
    }

    #[test]
    fn load_rejects_corrupted_config() {
        let out = small_output();
        let path = tmpfile("corrupt");
        out.save(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        // corrupt a split value beyond the layer count
        let bad = text.replacen("\"split\":", "\"split\":999, \"x\":", 1);
        std::fs::write(&path, bad).unwrap();
        assert!(SolverOutput::load_pareto(&path).is_err());
    }

    #[test]
    fn observation_pool_coverage_and_sampling() {
        let tb = Testbed::synthetic();
        let mut pool = ObservationPool::default();
        let out = small_output();
        let configs: Vec<Config> = out.pareto.iter().map(|p| p.config).collect();
        let mut rng = Pcg32::seeded(9);
        pool.ensure_coverage(&configs, 5, &tb, 20, &mut rng);
        assert!(pool.min_observations() >= 5);
        for c in &configs {
            let s = pool.sample(c, &mut rng).unwrap();
            assert!(s.latency_ms > 0.0);
        }
        // unknown config -> None
        let other = Config {
            net: Network::Vit,
            cpu_idx: 0,
            tpu: TpuMode::Off,
            gpu: false,
            split: 3,
        };
        assert!(pool.sample(&other, &mut rng).is_none());
    }
}
