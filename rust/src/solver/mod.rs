//! The DynaSplit *Solver* — the Offline Phase (§4.2).
//!
//! Drives the MOOP search (NSGA-III, or grid for ablations) over the
//! configuration space, evaluating each trial on the testbed (simulated
//! per DESIGN.md §Substitutions) averaged over a batch of inferences,
//! then extracts the non-dominated configuration set the Controller
//! consumes online.
//!
//! * [`store`] — persistence of trial logs and the non-dominated set
//!   (JSON), plus the per-configuration observation pool the Simulation
//!   Experiment samples from (§6.2: "each configuration … evaluated at
//!   least five times … randomly sampled from the pool").

pub mod store;

use crate::nsga::{self, grid, sort, NsgaConfig, NsgaIII};
use crate::simulator::{Testbed, TrialResult};
use crate::space::{Config, Network, Space};
use crate::util::rng::Pcg32;

pub use store::{Observation, ObservationPool, ParetoEntry, SolverOutput};

/// Search strategy for the offline phase.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Strategy {
    /// NSGA-III (the paper's DynaSplit Solver).
    NsgaIII,
    /// Deterministic shuffled grid (the paper's ~80% exploration).
    Grid,
}

/// Offline-phase driver.
pub struct Solver<'tb> {
    pub testbed: &'tb Testbed,
    pub space: Space,
    /// Inferences averaged per trial (paper: 1,000).
    pub batch_per_trial: usize,
}

impl<'tb> Solver<'tb> {
    pub fn new(testbed: &'tb Testbed, net: Network) -> Solver<'tb> {
        Solver { testbed, space: Space::new(net), batch_per_trial: 1000 }
    }

    /// Budget as a fraction of the raw space size |X| — how the paper
    /// reports effort (20% of 966 ⇒ ~184 trials for VGG16, §6.3.4).
    pub fn trials_for_fraction(&self, fraction: f64) -> usize {
        ((self.space.cardinality() as f64 * fraction).round() as usize).max(8)
    }

    /// Run the offline phase and return (trial log, non-dominated set).
    pub fn run(&self, strategy: Strategy, max_trials: usize, seed: u64) -> SolverOutput {
        let mut rng = Pcg32::new(seed, 101);
        let mut trials: Vec<TrialResult> = Vec::new();
        let history: Vec<nsga::Individual> = match strategy {
            Strategy::NsgaIII => {
                let mut driver = NsgaIII::new(
                    self.space,
                    NsgaConfig::default(),
                    |config: &Config| {
                        let mut trial_rng = rng.fork(trials.len() as u64);
                        let t = self
                            .testbed
                            .run_trial_n(config, self.batch_per_trial, &mut trial_rng);
                        let objs = t.objectives();
                        trials.push(t);
                        objs
                    },
                );
                let mut search_rng = Pcg32::new(seed, 102);
                driver.run(max_trials, &mut search_rng);
                driver.history
            }
            Strategy::Grid => grid::run(&self.space, max_trials, seed, |config| {
                let mut trial_rng = rng.fork(trials.len() as u64);
                let t = self.testbed.run_trial_n(config, self.batch_per_trial, &mut trial_rng);
                let objs = t.objectives();
                trials.push(t);
                objs
            }),
        };

        let front = sort::pareto_filter(&history);
        let pareto: Vec<ParetoEntry> = front
            .iter()
            .map(|ind| ParetoEntry {
                config: ind.config,
                latency_ms: ind.objs[0],
                energy_j: ind.objs[1],
                accuracy: -ind.objs[2],
            })
            .collect();
        SolverOutput { net: self.space.net, strategy, seed, trials, pareto }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nsga::hypervolume::hypervolume;
    use crate::simulator::Testbed;

    fn quick_solver_output(strategy: Strategy, trials: usize, seed: u64) -> SolverOutput {
        let tb = {
            let mut t = Testbed::synthetic();
            t.batch_per_trial = 50; // keep tests fast
            t
        };
        let mut s = Solver::new(&tb, Network::Vgg16);
        s.batch_per_trial = 50;
        s.run(strategy, trials, seed)
    }

    #[test]
    fn budget_fraction_matches_paper() {
        let tb = Testbed::synthetic();
        let s = Solver::new(&tb, Network::Vgg16);
        // §6.3.4: 20% of the VGG16 space = 184 trials (paper: 184).
        assert_eq!(s.trials_for_fraction(0.2), 193);
        // note: the paper counts 184 because it samples 20% of the
        // *feasible* trials; both land within a few trials of each other.
    }

    #[test]
    fn pareto_set_nondominated_and_nonempty() {
        let out = quick_solver_output(Strategy::NsgaIII, 120, 1);
        assert!(!out.pareto.is_empty());
        assert!(out.trials.len() <= 120);
        for a in &out.pareto {
            for b in &out.pareto {
                let ad = [a.latency_ms, a.energy_j, -a.accuracy];
                let bd = [b.latency_ms, b.energy_j, -b.accuracy];
                assert!(!crate::nsga::dominates(&ad, &bd) || ad == bd);
            }
        }
    }

    #[test]
    fn front_contains_energy_and_latency_extremes() {
        let out = quick_solver_output(Strategy::NsgaIII, 200, 2);
        // the front must include something fast (cloud-ish) and something
        // frugal (edge-ish) — that's the whole point of the controller.
        let min_lat = out.pareto.iter().map(|p| p.latency_ms).fold(f64::INFINITY, f64::min);
        let min_energy = out.pareto.iter().map(|p| p.energy_j).fold(f64::INFINITY, f64::min);
        assert!(min_lat < 150.0, "no fast config on the front: {min_lat}");
        assert!(min_energy < 5.0, "no frugal config on the front: {min_energy}");
    }

    #[test]
    fn nsga_beats_random_grid_at_equal_budget() {
        // The 20%-budget NSGA front should dominate at least as much
        // hypervolume as a random 20% grid subset (averaged over seeds).
        let refp = [6000.0, 120.0, -0.5];
        let mut nsga_hv = 0.0;
        let mut grid_hv = 0.0;
        for seed in 0..3 {
            let n = quick_solver_output(Strategy::NsgaIII, 150, seed);
            let g = quick_solver_output(Strategy::Grid, 150, seed);
            let pts = |o: &SolverOutput| -> Vec<[f64; 3]> {
                o.pareto.iter().map(|p| [p.latency_ms, p.energy_j, -p.accuracy]).collect()
            };
            nsga_hv += hypervolume(&pts(&n), &refp);
            grid_hv += hypervolume(&pts(&g), &refp);
        }
        assert!(
            nsga_hv >= 0.95 * grid_hv,
            "NSGA hv {nsga_hv} clearly below grid hv {grid_hv}"
        );
    }

    #[test]
    fn deterministic_given_seed() {
        let a = quick_solver_output(Strategy::NsgaIII, 60, 7);
        let b = quick_solver_output(Strategy::NsgaIII, 60, 7);
        assert_eq!(a.pareto.len(), b.pareto.len());
        for (x, y) in a.pareto.iter().zip(&b.pareto) {
            assert_eq!(x.config, y.config);
            assert_eq!(x.latency_ms, y.latency_ms);
        }
    }

    #[test]
    fn grid_covers_distinct_configs() {
        let out = quick_solver_output(Strategy::Grid, 100, 3);
        let mut genes: Vec<_> = out.trials.iter().map(|t| {
            let c = t.config;
            (c.cpu_idx, c.tpu as usize, c.gpu, c.split)
        }).collect();
        genes.sort();
        genes.dedup();
        assert_eq!(genes.len(), out.trials.len());
    }
}
