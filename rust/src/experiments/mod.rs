//! Experiment drivers — one module per figure/table of the paper's
//! evaluation (§6), each printing a paper-vs-measured report.  See the
//! experiment index in DESIGN.md §6.
//!
//! | module        | reproduces |
//! |---------------|------------|
//! | [`prelim`]    | Fig. 2a–2e (preliminary study)       |
//! | [`bounds`]    | Table 2 (latency bounds + configs)   |
//! | [`workload_dist`] | Fig. 5 (QoS distributions)       |
//! | [`testbed_exp`]   | Fig. 6–9 + headline (50 requests)|
//! | [`ablation`]  | Fig. 10 (20% vs ~80% search)         |
//! | [`simulation`]| Fig. 11–14 (10,000 requests)         |
//! | [`overhead`]  | Fig. 15 (controller overhead)        |
//! | [`serving`]   | beyond-paper: serving-pipeline throughput (policies × workers × cache) |
//! | [`adaptation`]| beyond-paper: closed-loop drift → re-solve → hot-swap recovery |
//! | [`mixed`]     | beyond-paper: mixed-network serving (vgg16 + vit, one pipeline) |
//! | [`scale`]     | beyond-paper: fleet-scale sweep (shards × workers, discrete-event clock) |
//! | [`chaos`]     | beyond-paper: chaos serving (fault injection × recovery modes, DESIGN.md §15) |

pub mod ablation;
pub mod adaptation;
pub mod chaos;
pub mod extensions;
pub mod bounds;
pub mod mixed;
pub mod overhead;
pub mod prelim;
pub mod scale;
pub mod serving;
pub mod simulation;
pub mod small_models;
pub mod testbed_exp;
pub mod workload_dist;

use crate::model::Manifest;
use crate::simulator::{AccuracyTable, Testbed};

/// Shared experiment context: the simulated testbed with the best
/// available accuracy table.
pub struct Ctx {
    pub testbed: Testbed,
    /// Where the accuracy table came from ("manifest", "synthetic").
    pub accuracy_origin: &'static str,
}

impl Ctx {
    /// Prefer the python-oracle expectations from `artifacts/manifest.json`
    /// (or the PJRT-measured cache when present); fall back to the
    /// synthetic table so simulator-only experiments run without
    /// artifacts.
    pub fn load(artifacts_dir: &str) -> Ctx {
        // measured (rust/PJRT) cache takes precedence if present
        let measured = std::path::Path::new(artifacts_dir).join("accuracy_rust.json");
        if let Ok(v) = crate::util::json::Json::parse_file(&measured) {
            if let Ok(m) = crate::runtime::evaluate::MeasuredAccuracy::from_json(&v) {
                return Ctx { testbed: Testbed::new(m.to_table()), accuracy_origin: "measured" };
            }
        }
        if let Ok(manifest) = Manifest::load(artifacts_dir) {
            if let Ok(table) = AccuracyTable::from_manifest(&manifest) {
                return Ctx { testbed: Testbed::new(table), accuracy_origin: "manifest" };
            }
        }
        Ctx { testbed: Testbed::synthetic(), accuracy_origin: "synthetic" }
    }

    /// Synthetic context for tests.
    pub fn synthetic() -> Ctx {
        Ctx { testbed: Testbed::synthetic(), accuracy_origin: "synthetic" }
    }
}

/// Paper-vs-measured comparison row helper used across reports.
pub fn compare_row(label: &str, paper: f64, measured: f64, unit: &str) -> [String; 4] {
    let ratio = if paper.abs() > 1e-12 { measured / paper } else { f64::NAN };
    [
        label.to_string(),
        format!("{paper:.1} {unit}"),
        format!("{measured:.1} {unit}"),
        format!("{ratio:.2}x"),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ctx_falls_back_to_synthetic() {
        let ctx = Ctx::load("/nonexistent/artifacts");
        assert_eq!(ctx.accuracy_origin, "synthetic");
    }

    #[test]
    fn compare_row_format() {
        let row = compare_row("x", 100.0, 90.0, "ms");
        assert_eq!(row[1], "100.0 ms");
        assert_eq!(row[3], "0.90x");
    }
}
