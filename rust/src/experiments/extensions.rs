//! Extensions from the paper's Discussion (§6.6) — implemented as
//! ablations the paper proposes but does not evaluate:
//!
//! 1. **On-demand (serverless) deployment**: the paper's testbed keeps an
//!    always-on cloud with pre-loaded models and notes that real
//!    deployments pay cold-start latency.  We add a cold-start model to
//!    the executor and measure how QoS satisfaction degrades.
//! 2. **Request clustering**: the paper suggests clustering requests by
//!    QoS to avoid frequent reconfiguration.  We implement a quantized-
//!    QoS scheduler (requests within a QoS bucket share one
//!    configuration) and measure the apply-overhead reduction vs the
//!    metric cost.

use crate::controller::policy::{ConfigSet, HysteresisPolicy, PolicyDecision, SchedulingPolicy};
use crate::controller::{apply::Applier, ExecOutcome, Executor};
use crate::metrics::{MetricSet, RequestRecord};
use crate::simulator::Testbed;
use crate::solver::{ParetoEntry, Solver, Strategy};
use crate::space::Network;
use crate::util::rng::Pcg32;
use crate::util::table::Table;
use crate::workload::{Request, WorkloadGen};

use super::Ctx;

// ---------------------------------------------------------------------
// 1. Cold-start (serverless cloud) ablation
// ---------------------------------------------------------------------

/// Cold-start model: if the cloud was not used for `keep_warm_s` of
/// simulated time, the next cloud-touching request pays `cold_start_ms`.
pub struct ColdStartExecutor<'tb> {
    pub testbed: &'tb Testbed,
    pub rng: Pcg32,
    pub cold_start_ms: f64,
    pub keep_warm_requests: usize,
    idle_streak: usize,
}

impl<'tb> ColdStartExecutor<'tb> {
    pub fn new(testbed: &'tb Testbed, seed: u64, cold_start_ms: f64, keep_warm: usize) -> Self {
        ColdStartExecutor {
            testbed,
            rng: Pcg32::new(seed, 111),
            cold_start_ms,
            keep_warm_requests: keep_warm,
            idle_streak: keep_warm + 1, // first cloud touch is cold
        }
    }
}

impl<'tb> Executor for ColdStartExecutor<'tb> {
    fn execute(&mut self, request: &Request, config: &crate::space::Config) -> ExecOutcome {
        let mut r = self.rng.fork(request.seed);
        let t = self.testbed.run_trial_n(config, request.inferences.min(1000), &mut r);
        let mut latency = t.latency_ms;
        if config.is_edge_only() {
            self.idle_streak += 1;
        } else {
            if self.idle_streak > self.keep_warm_requests {
                latency += self.cold_start_ms; // container boot + model load
            }
            self.idle_streak = 0;
        }
        ExecOutcome {
            latency_ms: latency,
            energy_j: t.energy_j,
            edge_energy_j: t.edge_energy_j,
            cloud_energy_j: t.cloud_energy_j,
            accuracy: t.accuracy,
        }
    }
}

/// Compare always-on vs serverless-cold-start cloud for DynaSplit.
pub struct ColdStartResult {
    pub warm: MetricSet,
    pub cold: MetricSet,
    pub cold_start_ms: f64,
}

pub fn run_cold_start(ctx: &Ctx, n_requests: usize, cold_start_ms: f64, seed: u64) -> ColdStartResult {
    let net = Network::Vgg16;
    let mut solver = Solver::new(&ctx.testbed, net);
    solver.batch_per_trial = 300;
    let pareto = solver.run(Strategy::NsgaIII, solver.trials_for_fraction(0.2), seed).pareto;
    let gen = WorkloadGen::paper(net);
    let mut rng = Pcg32::new(seed, 112);
    let requests = gen.generate(n_requests, &mut rng);

    let mut warm_ctl = crate::controller::Controller::new(pareto.clone(), seed);
    let mut warm_ex =
        crate::controller::SimExecutor::Fresh { testbed: &ctx.testbed, rng: Pcg32::new(seed, 113) };
    let warm = warm_ctl.serve(&requests, &mut warm_ex, "always-on");

    let mut cold_ctl = crate::controller::Controller::new(pareto, seed);
    let mut cold_ex = ColdStartExecutor::new(&ctx.testbed, seed, cold_start_ms, 3);
    let cold = cold_ctl.serve(&requests, &mut cold_ex, "serverless");
    ColdStartResult { warm, cold, cold_start_ms }
}

pub fn print_cold_start(r: &ColdStartResult) {
    println!(
        "\n== §6.6 extension — serverless cloud with {:.0} ms cold starts ==",
        r.cold_start_ms
    );
    let mut t = Table::new(["deployment", "QoS met", "lat median", "energy median"]);
    for m in [&r.warm, &r.cold] {
        t.row([
            m.strategy.clone(),
            format!("{:.0}%", m.qos_met_fraction() * 100.0),
            format!("{:.0} ms", m.latency_summary().median),
            format!("{:.1} J", m.energy_summary().median),
        ]);
    }
    t.print();
    println!("paper §6.6: on-demand services 'may incur cold-start latencies' — quantified here.");
}

// ---------------------------------------------------------------------
// 2. QoS-clustered scheduling
// ---------------------------------------------------------------------

/// Clustered (sticky) controller — the §6.6 "clustering user requests"
/// proposal made concrete.  The hysteresis logic itself now lives in
/// the composable [`HysteresisPolicy`] (ROADMAP "policy zoo"), which
/// also plugs straight into the concurrent serving pipeline; this
/// sequential wrapper keeps the ablation's apply-overhead accounting.
pub struct ClusteredController {
    set: ConfigSet,
    policy: HysteresisPolicy,
    applier: Applier,
    rng: Pcg32,
}

impl ClusteredController {
    pub fn new(entries: Vec<ParetoEntry>, buckets: usize, min_ms: f64, max_ms: f64, seed: u64) -> Self {
        ClusteredController {
            set: ConfigSet::new(entries),
            policy: HysteresisPolicy::new(buckets, min_ms, max_ms, 3.0),
            applier: Applier::default(),
            rng: Pcg32::new(seed, 121),
        }
    }

    /// Bucket floor of the underlying policy (exposed for tests).
    pub fn bucket_floor(&self, qos_ms: f64) -> f64 {
        self.policy.bucket_floor(qos_ms)
    }

    pub fn serve<E: Executor>(&mut self, requests: &[Request], ex: &mut E, name: &str) -> MetricSet {
        let records = requests
            .iter()
            .map(|req| {
                let entry = match self.policy.decide(&self.set, req.qos_ms) {
                    PolicyDecision::Run(i) => self.set.entries()[i].clone(),
                    PolicyDecision::Reject => unreachable!("non-empty configuration set"),
                };
                let apply_ms = self.applier.apply(&entry.config, &mut self.rng);
                let out = ex.execute(req, &entry.config);
                RequestRecord {
                    request_id: req.id,
                    qos_ms: req.qos_ms,
                    config: entry.config,
                    latency_ms: out.latency_ms,
                    energy_j: out.energy_j,
                    edge_energy_j: out.edge_energy_j,
                    cloud_energy_j: out.cloud_energy_j,
                    accuracy: out.accuracy,
                    select_overhead_ms: 0.0,
                    apply_overhead_ms: apply_ms,
                }
            })
            .collect();
        MetricSet::new(name, records)
    }
}

pub struct ClusterResult {
    pub plain: MetricSet,
    pub clustered: MetricSet,
    pub buckets: usize,
}

pub fn run_clustering(ctx: &Ctx, n_requests: usize, buckets: usize, seed: u64) -> ClusterResult {
    let net = Network::Vgg16;
    let mut solver = Solver::new(&ctx.testbed, net);
    solver.batch_per_trial = 300;
    let pareto = solver.run(Strategy::NsgaIII, solver.trials_for_fraction(0.2), seed).pareto;
    let gen = WorkloadGen::paper(net);
    let mut rng = Pcg32::new(seed, 122);
    let requests = gen.generate(n_requests, &mut rng);
    let bounds = crate::workload::LatencyBounds::paper(net);

    let mut plain_ctl = crate::controller::Controller::new(pareto.clone(), seed);
    let mut ex1 =
        crate::controller::SimExecutor::Fresh { testbed: &ctx.testbed, rng: Pcg32::new(seed, 123) };
    let plain = plain_ctl.serve(&requests, &mut ex1, "per-request");

    let mut cl = ClusteredController::new(pareto, buckets, bounds.min_ms, bounds.max_ms, seed);
    let mut ex2 =
        crate::controller::SimExecutor::Fresh { testbed: &ctx.testbed, rng: Pcg32::new(seed, 123) };
    let clustered = cl.serve(&requests, &mut ex2, "clustered");
    ClusterResult { plain, clustered, buckets }
}

pub fn print_clustering(r: &ClusterResult) {
    println!("\n== §6.6 extension — QoS-clustered scheduling ({} buckets) ==", r.buckets);
    let mut t = Table::new([
        "scheduler", "QoS met", "energy median", "total apply overhead", "reconfigs",
    ]);
    for m in [&r.plain, &r.clustered] {
        let total_apply: f64 = m.records.iter().map(|x| x.apply_overhead_ms).sum();
        let reconfigs = m.records.iter().filter(|x| x.apply_overhead_ms > 1.0).count();
        t.row([
            m.strategy.clone(),
            format!("{:.0}%", m.qos_met_fraction() * 100.0),
            format!("{:.1} J", m.energy_summary().median),
            format!("{:.0} ms", total_apply),
            format!("{reconfigs}"),
        ]);
    }
    t.print();
    println!("paper §6.6: clustering 'would reduce frequent configuration changes and \
              decision overhead' — quantified here (fewer reconfigs, slightly more energy).");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cold_starts_hurt_qos() {
        let ctx = Ctx::synthetic();
        let r = run_cold_start(&ctx, 60, 800.0, 7);
        assert!(
            r.cold.qos_met_fraction() <= r.warm.qos_met_fraction(),
            "cold {} vs warm {}",
            r.cold.qos_met_fraction(),
            r.warm.qos_met_fraction()
        );
        // latency medians should not be lower under cold starts
        assert!(r.cold.latency_summary().mean >= r.warm.latency_summary().mean - 1.0);
    }

    #[test]
    fn clustering_reduces_reconfigurations() {
        let ctx = Ctx::synthetic();
        let r = run_clustering(&ctx, 80, 6, 8);
        let reconf = |m: &MetricSet| m.records.iter().filter(|x| x.apply_overhead_ms > 1.0).count();
        assert!(
            reconf(&r.clustered) < reconf(&r.plain),
            "clustered {} vs plain {}",
            reconf(&r.clustered),
            reconf(&r.plain)
        );
    }

    #[test]
    fn clustering_preserves_qos_floor_semantics() {
        // selecting for the bucket *floor* must not violate more than the
        // per-request scheduler by a wide margin
        let ctx = Ctx::synthetic();
        let r = run_clustering(&ctx, 80, 6, 9);
        assert!(
            r.clustered.qos_met_fraction() >= r.plain.qos_met_fraction() - 0.1,
            "clustered {} vs plain {}",
            r.clustered.qos_met_fraction(),
            r.plain.qos_met_fraction()
        );
    }

    #[test]
    fn bucket_floor_is_monotone_and_bounded() {
        let cl = ClusteredController::new(
            vec![ParetoEntry {
                config: crate::space::Space::new(Network::Vgg16).decode(&[6, 0, 0, 22]),
                latency_ms: 1.0,
                energy_j: 1.0,
                accuracy: 1.0,
            }],
            8,
            90.6,
            5026.8,
            1,
        );
        let mut last = 0.0;
        for q in [90.6, 150.0, 400.0, 1000.0, 3000.0, 5026.8] {
            let f = cl.bucket_floor(q);
            assert!(f <= q + 1e-9, "floor {f} above qos {q}");
            assert!(f >= last, "floor not monotone");
            assert!(f >= 90.6 - 1e-9);
            last = f;
        }
    }

    #[test]
    fn reports_print() {
        let ctx = Ctx::synthetic();
        print_cold_start(&run_cold_start(&ctx, 20, 500.0, 10));
        print_clustering(&run_clustering(&ctx, 20, 4, 10));
    }
}
