//! Fig. 15 + §6.5 — controller run-time overhead: startup load+sort,
//! per-request configuration selection, configuration application.

use crate::controller::{Controller, SimExecutor};
use crate::solver::{Solver, Strategy};
use crate::space::Network;
use crate::util::rng::Pcg32;
use crate::util::stats::Summary;
use crate::util::table::Table;
use crate::workload::WorkloadGen;

use super::Ctx;

#[derive(Debug, Clone)]
pub struct OverheadResult {
    pub net: Network,
    pub startup_ms: f64,
    pub config_count: usize,
    pub select_ms: Summary,
    pub apply_ms: Summary,
    /// Overheads relative to the median edge latency (§6.5's comparison).
    pub median_edge_latency_ms: f64,
}

pub fn run(ctx: &Ctx, net: Network, n_requests: usize, trial_batch: usize, seed: u64) -> OverheadResult {
    let mut solver = Solver::new(&ctx.testbed, net);
    solver.batch_per_trial = trial_batch;
    let out = solver.run(Strategy::NsgaIII, solver.trials_for_fraction(0.2), seed);

    let mut controller = Controller::new(out.pareto, seed);
    let gen = WorkloadGen::paper(net);
    let mut rng = Pcg32::new(seed, 81);
    let requests = gen.generate(n_requests, &mut rng);
    let mut ex = SimExecutor::Fresh { testbed: &ctx.testbed, rng: Pcg32::new(seed, 82) };
    let metrics = controller.serve(&requests, &mut ex, "dynasplit");

    let edge_cfg = super::testbed_exp::edge_baseline(net);
    let mut r2 = Pcg32::new(seed, 83);
    let edge_lat = ctx.testbed.run_trial_n(&edge_cfg, trial_batch, &mut r2).latency_ms;

    OverheadResult {
        net,
        startup_ms: controller.startup.load_sort_ms,
        config_count: controller.startup.config_count,
        select_ms: Summary::of(
            &metrics.records.iter().map(|r| r.select_overhead_ms).collect::<Vec<_>>(),
        ),
        apply_ms: Summary::of(
            &metrics.records.iter().map(|r| r.apply_overhead_ms).collect::<Vec<_>>(),
        ),
        median_edge_latency_ms: edge_lat,
    }
}

pub fn print_report(results: &[OverheadResult]) {
    println!("\n== Fig. 15 / §6.5 — controller overhead ==");
    let mut t = Table::new([
        "network", "|configs|", "startup", "select med", "select max", "apply med", "apply max",
    ]);
    for r in results {
        t.row([
            r.net.name().to_string(),
            format!("{}", r.config_count),
            format!("{:.2} ms", r.startup_ms),
            format!("{:.4} ms", r.select_ms.median),
            format!("{:.4} ms", r.select_ms.max),
            format!("{:.0} ms", r.apply_ms.median),
            format!("{:.0} ms", r.apply_ms.max),
        ]);
    }
    t.print();
    println!("paper (python on RPi3): startup 4.2 s; select ≤12 ms (medians <5/<10 ms); \
              apply mostly <200 ms, median <150 ms, outliers ~500 ms.");
    println!("note: selection in rust is orders of magnitude below the paper's python/RPi3 \
              figures; apply is modeled hardware latency and reproduces Fig. 15b.");
    for r in results {
        println!(
            "{}: select adds {:.3}% and apply adds {:.1}% of the median edge latency \
             ({:.0} ms) — paper: 0.96%/32.14% (VGG16), 0.23%/2.95% (ViT).",
            r.net.name(),
            100.0 * r.select_ms.median / r.median_edge_latency_ms,
            100.0 * r.apply_ms.median / r.median_edge_latency_ms,
            r.median_edge_latency_ms
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn result(net: Network) -> OverheadResult {
        run(&Ctx::synthetic(), net, 50, 40, 11)
    }

    #[test]
    fn select_is_fast_and_apply_in_fig15_envelope() {
        let r = result(Network::Vgg16);
        assert!(r.select_ms.max < 1.0, "select max {} ms", r.select_ms.max);
        assert!(r.apply_ms.median < 150.0, "apply median {}", r.apply_ms.median);
        assert!(r.apply_ms.max < 800.0, "apply max {}", r.apply_ms.max);
    }

    #[test]
    fn startup_loads_quickly_for_small_sets() {
        let r = result(Network::Vit);
        // paper: 4.2 s python startup; rust sorting of ~15 entries: < 50 ms.
        assert!(r.startup_ms < 50.0, "{}", r.startup_ms);
        assert!(r.config_count > 0);
    }

    #[test]
    fn report_prints() {
        print_report(&[result(Network::Vgg16)]);
    }
}
