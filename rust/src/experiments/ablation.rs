//! Fig. 10 — DynaSplit's 20% search vs the ~80% grid exploration
//! (§6.3.4): both produce non-dominated sets; the controller's behaviour
//! under the same workload should be nearly identical.

use crate::solver::{Solver, Strategy};
use crate::space::Network;
use crate::util::rng::Pcg32;
use crate::util::table::Table;
use crate::workload::WorkloadGen;

use super::testbed_exp::serve_strategies;
use super::Ctx;
use crate::metrics::MetricSet;

#[derive(Debug, Clone)]
pub struct AblationResult {
    pub small: MetricSet,  // 20% NSGA-III
    pub large: MetricSet,  // ~80% grid
    pub small_trials: usize,
    pub large_trials: usize,
    pub small_pareto: usize,
    pub large_pareto: usize,
}

/// Run both searches and serve the same workload from each result.
pub fn run(ctx: &Ctx, n_requests: usize, trial_batch: usize, seed: u64) -> AblationResult {
    let net = Network::Vgg16; // the paper ablates on VGG16 only
    let mut solver = Solver::new(&ctx.testbed, net);
    solver.batch_per_trial = trial_batch;

    let small_trials = solver.trials_for_fraction(0.2); // paper: 184
    let large_trials = solver.trials_for_fraction(0.815); // paper: 747
    let small_out = solver.run(Strategy::NsgaIII, small_trials, seed);
    let large_out = solver.run(Strategy::Grid, large_trials, seed);

    let gen = WorkloadGen::paper(net);
    let mut rng = Pcg32::new(seed, 71);
    let requests = gen.generate(n_requests, &mut rng);

    // same workload + same executor seeds for an apples-to-apples compare
    let small = serve_strategies(&ctx.testbed, small_out.pareto.clone(), &requests, seed)
        .dynasplit;
    let large = serve_strategies(&ctx.testbed, large_out.pareto.clone(), &requests, seed)
        .dynasplit;
    AblationResult {
        small,
        large,
        small_trials,
        large_trials,
        small_pareto: small_out.pareto.len(),
        large_pareto: large_out.pareto.len(),
    }
}

pub fn print_report(r: &AblationResult) {
    println!(
        "\n== Fig. 10 — 20% search ({} trials, |front| {}) vs ~80% search ({} trials, |front| {}) ==",
        r.small_trials, r.small_pareto, r.large_trials, r.large_pareto
    );
    let mut t = Table::new([
        "search", "cloud/split/edge", "lat median", "violations", "med exceed", "energy median",
    ]);
    for m in [&r.small, &r.large] {
        let (c, s, e) = m.placement_counts();
        let exceed = m
            .violation_summary()
            .map(|v| format!("{:.0} ms", v.median))
            .unwrap_or_else(|| "-".to_string());
        t.row([
            if std::ptr::eq(m, &r.small) { "20% (NSGA-III)" } else { "80% (grid)" }.to_string(),
            format!("{c}/{s}/{e}"),
            format!("{:.0} ms", m.latency_summary().median),
            format!("{}", m.violations()),
            exceed,
            format!("{:.1} J", m.energy_summary().median),
        ]);
    }
    t.print();
    println!("paper: identical cloud counts, ≤1 data-point split/edge differences, \
              no significant latency/violation/energy differences.");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn twenty_percent_matches_eighty_percent() {
        let r = run(&Ctx::synthetic(), 50, 40, 9);
        // Fig. 10: the two searches must produce near-identical outcomes.
        let lat_ratio =
            r.small.latency_summary().median / r.large.latency_summary().median;
        assert!((0.5..2.0).contains(&lat_ratio), "latency ratio {lat_ratio}");
        let e_ratio = r.small.energy_summary().median / r.large.energy_summary().median;
        assert!((0.5..2.0).contains(&e_ratio), "energy ratio {e_ratio}");
        let dv = (r.small.violations() as i64 - r.large.violations() as i64).abs();
        assert!(dv <= 10, "violation counts differ by {dv}");
    }

    #[test]
    fn budgets_match_paper_scale() {
        let ctx = Ctx::synthetic();
        let mut solver = Solver::new(&ctx.testbed, Network::Vgg16);
        solver.batch_per_trial = 10;
        // paper: 184 and 747 trials; ours derive from |X| = 966.
        assert!((150..250).contains(&solver.trials_for_fraction(0.2)));
        assert!((700..800).contains(&solver.trials_for_fraction(0.815)));
    }

    #[test]
    fn report_prints() {
        print_report(&run(&Ctx::synthetic(), 30, 30, 10));
    }
}
