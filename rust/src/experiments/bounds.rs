//! Table 2 — latency bounds per network, with the configurations that
//! achieve them (full feasible-space sweep, the GridSampler run).

use super::{compare_row, Ctx};
use crate::nsga::grid;
use crate::simulator::TrialResult;
use crate::space::{Network, Space};
use crate::util::rng::Pcg32;
use crate::util::table::Table;

#[derive(Debug, Clone)]
pub struct Bounds {
    pub net: Network,
    pub min: TrialResult,
    pub max: TrialResult,
}

/// Sweep the full feasible space of `net` and find the latency extremes.
pub fn run(ctx: &Ctx, net: Network, batch: usize, seed: u64) -> Bounds {
    let space = Space::new(net);
    let mut rng = Pcg32::new(seed, 31);
    let mut results: Vec<TrialResult> = Vec::new();
    grid::run_full(&space, |config| {
        let t = ctx.testbed.run_trial_n(config, batch, &mut rng);
        let objs = t.objectives();
        results.push(t);
        objs
    });
    let min = results
        .iter()
        .min_by(|a, b| a.latency_ms.total_cmp(&b.latency_ms))
        .unwrap()
        .clone();
    let max = results
        .iter()
        .max_by(|a, b| a.latency_ms.total_cmp(&b.latency_ms))
        .unwrap()
        .clone();
    Bounds { net, min, max }
}

pub fn print_report(vgg: &Bounds, vit: &Bounds) {
    println!("\n== Table 2 — latency bounds (paper vs measured) ==");
    let mut t = Table::new(["quantity", "paper", "measured", "ratio"]);
    t.row(compare_row("VGG16 min latency", 90.6, vgg.min.latency_ms, "ms"));
    t.row(compare_row("VGG16 max latency", 5026.8, vgg.max.latency_ms, "ms"));
    t.row(compare_row("ViT   min latency", 118.8, vit.min.latency_ms, "ms"));
    t.row(compare_row("ViT   max latency", 10_287.6, vit.max.latency_ms, "ms"));
    t.print();
    println!("bound-achieving configurations:");
    let mut t = Table::new(["bound", "configuration", "paper configuration"]);
    t.row([
        "VGG16 min".to_string(),
        vgg.min.config.describe(),
        "CPU 1.2, TPU no, GPU yes, split 0".to_string(),
    ]);
    t.row([
        "VGG16 max".to_string(),
        vgg.max.config.describe(),
        "CPU 0.6, TPU no, GPU no, split 20".to_string(),
    ]);
    t.row([
        "ViT   min".to_string(),
        vit.min.config.describe(),
        "CPU 1.4, TPU no, GPU yes, split 0".to_string(),
    ]);
    t.row([
        "ViT   max".to_string(),
        vit.max.config.describe(),
        "CPU 0.6, TPU no, GPU no, split 18".to_string(),
    ]);
    t.print();
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vgg_bounds_match_paper_shape() {
        let ctx = Ctx::synthetic();
        let b = run(&ctx, Network::Vgg16, 25, 1);
        // min: a cloud-only GPU config in the ~90-115 ms range
        assert!(b.min.config.is_cloud_only(), "{:?}", b.min.config);
        assert!(b.min.config.gpu);
        assert!((80.0..130.0).contains(&b.min.latency_ms), "{}", b.min.latency_ms);
        // max: slowest CPU, no accelerators, mostly-edge split
        assert_eq!(b.max.config.cpu_idx, 0);
        assert!(!b.max.config.gpu);
        assert!(b.max.config.split >= 18, "{:?}", b.max.config);
        assert!((3800.0..7000.0).contains(&b.max.latency_ms), "{}", b.max.latency_ms);
    }

    #[test]
    fn vit_bounds_match_paper_shape() {
        let ctx = Ctx::synthetic();
        let b = run(&ctx, Network::Vit, 25, 2);
        // ViT's patchify layer is free (0 MACs) and its output is exactly
        // input-sized, so k=0 and k=1 tie and jitter decides the argmin:
        // accept either as "cloud-like".
        assert!(b.min.config.split <= 1, "{:?}", b.min.config);
        assert!(b.min.config.gpu);
        assert!((100.0..150.0).contains(&b.min.latency_ms), "{}", b.min.latency_ms);
        assert_eq!(b.max.config.cpu_idx, 0);
        assert!((8000.0..14_000.0).contains(&b.max.latency_ms), "{}", b.max.latency_ms);
    }

    #[test]
    fn report_prints() {
        let ctx = Ctx::synthetic();
        let vgg = run(&ctx, Network::Vgg16, 10, 3);
        let vit = run(&ctx, Network::Vit, 10, 3);
        print_report(&vgg, &vit);
    }
}
