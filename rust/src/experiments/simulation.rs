//! The Simulation Experiment (§6.4, Fig. 11–14): up to 10,000 requests,
//! served from the observation pool (each configuration evaluated ≥ 5
//! times on the testbed, then requests re-sample stored observations —
//! exactly the paper's §6.2 methodology).

use crate::controller::{Controller, SimExecutor, StaticBaseline};
use crate::solver::{ObservationPool, ParetoEntry, Solver, Strategy};
use crate::space::{Config, Network};
use crate::util::rng::Pcg32;
use crate::util::table::Table;
use crate::workload::WorkloadGen;

use super::testbed_exp::{
    cloud_baseline, edge_baseline, energy_entry, fastest_entry, StrategySet,
};
use super::Ctx;

/// Simulation-experiment output for one network.
#[derive(Debug, Clone)]
pub struct SimulationExp {
    pub net: Network,
    pub pareto: Vec<ParetoEntry>,
    pub strategies: StrategySet,
}

/// Run the simulation experiment (`n_requests` up to the paper's 10,000).
pub fn run(
    ctx: &Ctx,
    net: Network,
    n_requests: usize,
    trial_batch: usize,
    seed: u64,
) -> SimulationExp {
    // Offline phase (re-used for the observation pool).
    let mut solver = Solver::new(&ctx.testbed, net);
    solver.batch_per_trial = trial_batch;
    let trials = solver.trials_for_fraction(0.2);
    let out = solver.run(Strategy::NsgaIII, trials, seed);

    // Build the observation pool: solver trials + topped-up coverage for
    // every configuration any strategy can select (≥ 5 observations each).
    let mut pool = ObservationPool::default();
    for t in &out.trials {
        pool.record(t);
    }
    let mut coverage_configs: Vec<Config> =
        out.pareto.iter().map(|p| p.config).collect();
    coverage_configs.push(cloud_baseline(net));
    coverage_configs.push(edge_baseline(net));
    let mut rng = Pcg32::new(seed, 61);
    pool.ensure_coverage(&coverage_configs, 5, &ctx.testbed, trial_batch, &mut rng);

    // Workload.
    let gen = WorkloadGen::paper(net);
    let mut wl_rng = Pcg32::new(seed, 62);
    let requests = gen.generate(n_requests, &mut wl_rng);

    // Serve all five strategies from the pool.
    let exec = |s: u64| SimExecutor::Pool {
        pool: pool.clone(),
        testbed: &ctx.testbed,
        rng: Pcg32::new(seed, 300 + s),
    };
    let static_entry = |config: Config| ParetoEntry {
        config,
        latency_ms: f64::NAN,
        energy_j: f64::NAN,
        accuracy: f64::NAN,
    };
    let cloud = StaticBaseline { entry: static_entry(cloud_baseline(net)) }
        .serve(&requests, &mut exec(0), "cloud");
    let edge = StaticBaseline { entry: static_entry(edge_baseline(net)) }
        .serve(&requests, &mut exec(1), "edge");
    let latency = StaticBaseline { entry: fastest_entry(&out.pareto) }
        .serve(&requests, &mut exec(2), "latency");
    let energy = StaticBaseline { entry: energy_entry(&out.pareto) }
        .serve(&requests, &mut exec(3), "energy");
    let mut controller = Controller::new(out.pareto.clone(), seed);
    let dynasplit = controller.serve(&requests, &mut exec(4), "dynasplit");

    SimulationExp {
        net,
        pareto: out.pareto,
        strategies: StrategySet { cloud, edge, latency, energy, dynasplit },
    }
}

pub fn print_report(exp: &SimulationExp) {
    let s = &exp.strategies;
    let n = s.dynasplit.len();
    println!(
        "\n===== Simulation Experiment — {} ({} requests) =====",
        exp.net.name(),
        n
    );

    // --- Fig. 11: scheduling decisions ---
    let (cloud, split, edge) = s.dynasplit.placement_counts();
    println!("\n== Fig. 11 — scheduling decisions ==");
    let paper = match exp.net {
        Network::Vgg16 => "paper: 4% cloud, ~4857 split, ~4695 edge of 10k",
        Network::Vit => "paper: 1% cloud, 99% split, 0 edge",
    };
    println!(
        "measured: {cloud} cloud ({:.0}%) / {split} split ({:.0}%) / {edge} edge ({:.0}%)   ({paper})",
        100.0 * cloud as f64 / n as f64,
        100.0 * split as f64 / n as f64,
        100.0 * edge as f64 / n as f64
    );

    // --- Fig. 12-14 ---
    println!("\n== Fig. 12 — latency | Fig. 13 — QoS violations | Fig. 14 — energy ==");
    let mut t = Table::new([
        "strategy", "lat median", "violations", "viol rate", "med exceed", "energy median",
    ]);
    for m in s.all() {
        let med = m
            .violation_summary()
            .map(|v| format!("{:.0} ms", v.median))
            .unwrap_or_else(|| "-".to_string());
        t.row([
            m.strategy.clone(),
            format!("{:.0} ms", m.latency_summary().median),
            format!("{}", m.violations()),
            format!("{:.1}%", 100.0 * (1.0 - m.qos_met_fraction())),
            med,
            format!("{:.1} J", m.energy_summary().median),
        ]);
    }
    t.print();
    println!(
        "paper ({}): DynaSplit ~{}% violations; energy median {} J; \
         cloud/latency ~{} J; edge {} J",
        exp.net.name(),
        if exp.net == Network::Vgg16 { "5" } else { "14" },
        if exp.net == Network::Vgg16 { "62" } else { "89" },
        if exp.net == Network::Vgg16 { "69" } else { "91" },
        if exp.net == Network::Vgg16 { "2" } else { "17" },
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    fn exp(net: Network, n: usize) -> SimulationExp {
        run(&Ctx::synthetic(), net, n, 40, 5)
    }

    #[test]
    fn vgg_simulation_shape() {
        let e = exp(Network::Vgg16, 2000);
        let s = &e.strategies;
        // Fig. 13: DynaSplit violation rate far below edge/energy baselines
        let dyn_rate = 1.0 - s.dynasplit.qos_met_fraction();
        let edge_rate = 1.0 - s.edge.qos_met_fraction();
        assert!(dyn_rate < 0.25, "dyn violations {dyn_rate}");
        assert!(edge_rate > 2.0 * dyn_rate, "edge {edge_rate} vs dyn {dyn_rate}");
        // Fig. 14: energy ordering holds
        assert!(
            s.dynasplit.energy_summary().median < s.cloud.energy_summary().median
        );
    }

    #[test]
    fn pool_mode_is_fast_for_many_requests() {
        // 2,000 pool-served requests must not require 2,000 fresh trials —
        // wall-clock stays small.
        let sw = crate::serve::clock::Stopwatch::start();
        let _ = exp(Network::Vit, 2000);
        assert!(sw.elapsed().as_secs() < 30, "{:?}", sw.elapsed());
    }

    #[test]
    fn report_prints() {
        print_report(&exp(Network::Vgg16, 500));
    }
}
