//! Fleet-scale serving experiment (beyond-paper; ROADMAP
//! "million-user scale", DESIGN.md §14).
//!
//! Sweeps admission-queue shards × workers over one heterogeneous
//! device-fleet workload (diurnal nonhomogeneous Poisson arrivals plus
//! deterministic flash crowds, three device classes with distinct edge
//! speeds and QoS envelopes) under the **discrete-event clock**: batch
//! completions advance simulated time, so a multi-hour trace replays
//! in seconds of wall clock while keeping real-time queueing, expiry,
//! and shedding semantics.  The fleet deliberately offers more load
//! than the workers can absorb — the sweep reports each cell's
//! throughput ceiling, tail latency, and shed/expired counts, and the
//! per-shard report slices are asserted to reconcile exactly with the
//! aggregates.  A final cell hot-swaps the Pareto store mid-replay
//! under the largest shard count and verifies every completion's
//! `(epoch, digest)` stamp against the store registry: sharded
//! admission and work stealing never expose a torn store.

use std::sync::atomic::{AtomicUsize, Ordering};

use crate::adapt::{ConfigStore, StoreMap};
use crate::controller::policy::ConfigSet;
use crate::controller::{ExecOutcome, Executor, PaperPolicy, PerRequestSimExecutor};
use crate::serve::{run_pipeline, run_pipeline_stores, PipelineConfig, ServeOutcome, ServeReport};
use crate::simulator::Testbed;
use crate::solver::{Solver, Strategy};
use crate::space::{Config, Network};
use crate::util::rng::Pcg32;
use crate::util::table::Table;
use crate::workload::{FleetSpec, Request, TimedRequest};

use super::Ctx;

/// Executor stream selector shared by every cell: outcomes depend only
/// on the request, so cells are comparable across shard/worker counts.
const EXEC_STREAM: u64 = 7777;

/// Mean fleet arrival rate.  Deliberately above what the smaller cells
/// can serve — the sweep is about the throughput ceiling, not a
/// comfortably provisioned pipeline.
const RATE_PER_S: f64 = 12.0;

/// Per-shard admission queue capacity for every cell.
const QUEUE_PER_SHARD: usize = 2048;

/// Routes each request to the testbed of its device class: the class
/// is carried in the request seed ([`FleetSpec::class_of`]), so the
/// outcome stays a pure function of `(request, config)` — the
/// pipeline's order-independence contract — while the fleet stays
/// heterogeneous.
pub struct FleetExecutor<'a> {
    pub spec: &'a FleetSpec,
    pub worlds: &'a [Testbed],
    pub stream: u64,
}

impl Executor for FleetExecutor<'_> {
    fn execute(&mut self, request: &Request, config: &Config) -> ExecOutcome {
        let class = self.spec.class_of(request.seed);
        let mut ex = PerRequestSimExecutor { testbed: &self.worlds[class], stream: self.stream };
        ex.execute(request, config)
    }
}

/// One forked testbed per device class: the class's `edge_speed`
/// throttles both networks' edge models (1.0 = the reference device).
pub fn class_worlds(base: &Testbed, spec: &FleetSpec) -> Vec<Testbed> {
    spec.classes
        .iter()
        .map(|c| {
            let mut tb = base.clone();
            tb.vgg.throttle_edge(c.edge_speed);
            tb.vit.throttle_edge(c.edge_speed);
            tb
        })
        .collect()
}

/// One pipeline replay under a (shards, workers) combination.
#[derive(Debug, Clone)]
pub struct Cell {
    pub shards: usize,
    pub workers: usize,
    pub report: ServeReport,
}

impl Cell {
    /// Completed requests per wall-clock second (the replay ceiling).
    pub fn wall_throughput(&self) -> f64 {
        self.report.completed() as f64 / (self.report.wall_ms / 1000.0).max(1e-9)
    }
}

pub struct ScaleExperiment {
    pub net: Network,
    pub requests: usize,
    pub devices: usize,
    /// Simulated arrival horizon of the fleet trace (last arrival).
    pub horizon_ms: f64,
    pub cells: Vec<Cell>,
    /// Store epochs observed by completions in the hot-swap cell.
    pub epochs_observed: Vec<u64>,
    /// Every `(epoch, digest)` stamp in the hot-swap cell was a
    /// registered installation (asserted during the run).
    pub epochs_torn_free: bool,
}

/// The fixed sweep grid: shards × workers, small cells first so the
/// throughput ceiling is visible as workers (and shards) grow.
const GRID: [(usize, usize); 5] = [(1, 4), (4, 4), (8, 4), (4, 16), (8, 16)];

pub fn run(ctx: &Ctx, requests: usize, devices: usize, seed: u64) -> ScaleExperiment {
    let net = Network::Vgg16;
    // offline phase: one 20%-style search shared by every cell
    let mut solver = Solver::new(&ctx.testbed, net);
    solver.batch_per_trial = 60;
    let pareto = solver.run(Strategy::NsgaIII, 120, seed).pareto;
    let set = ConfigSet::new(pareto);

    // the fleet: heterogeneous device classes, diurnal + flash arrivals
    let spec = FleetSpec::synthetic(net, devices, RATE_PER_S);
    let worlds = class_worlds(&ctx.testbed, &spec);
    let mut rng = Pcg32::new(seed, 271);
    let tl = spec.timeline(requests, &mut rng);
    let horizon_ms = tl.last().map_or(0.0, |tr| tr.arrival_ms);

    let mut cells = Vec::new();
    for (shards, workers) in GRID {
        let cfg = PipelineConfig {
            workers,
            queue_capacity: QUEUE_PER_SHARD,
            max_batch: 4,
            time_scale: 0.0,
            seed,
            reuse: true,
            shards,
            discrete: true,
        };
        let report = run_pipeline(&set, &PaperPolicy, &tl, &cfg, |_| {
            Ok(FleetExecutor { spec: &spec, worlds: &worlds, stream: EXEC_STREAM })
        })
        .expect("scale cell run");
        assert_eq!(report.records.len(), requests, "s{shards} w{workers}: request conservation");
        reconcile(&report);
        cells.push(Cell { shards, workers, report });
    }

    // hot-swap cell: the Pareto store swaps mid-replay under the
    // largest shard count; every completion must stamp a registered
    // (epoch, digest) — per-shard feeders and work stealing included
    let (epochs_observed, epochs_torn_free) =
        swap_cell(ctx, &set, &spec, &worlds, &tl, seed);

    ScaleExperiment { net, requests, devices, horizon_ms, cells, epochs_observed, epochs_torn_free }
}

/// Per-shard slices must reconcile exactly with the aggregates — the
/// contention-free counters and the record partition agree bitwise.
fn reconcile(report: &ServeReport) {
    let parts = report.shard_breakdown();
    assert_eq!(parts.len(), report.shards.max(1));
    assert_eq!(parts.iter().map(|b| b.requests).sum::<usize>(), report.records.len());
    assert_eq!(parts.iter().map(|b| b.done).sum::<usize>(), report.completed());
    assert_eq!(parts.iter().map(|b| b.expired).sum::<usize>(), report.expired_in_queue());
    assert_eq!(
        parts.iter().map(|b| b.rejected_queue_full).sum::<usize>(),
        report.rejected_queue_full()
    );
    let energy: f64 = parts.iter().map(|b| b.energy_sum_j).sum();
    let total = report.mean_energy_j() * report.completed() as f64;
    if report.completed() > 0 {
        assert!((energy - total).abs() < 1e-6, "per-shard energy reconciles");
    }
}

/// Executor that hot-swaps the store after `at` completions, then
/// keeps routing through the fleet executor.
struct SwapOnce<'a> {
    inner: FleetExecutor<'a>,
    executed: &'a AtomicUsize,
    at: usize,
    store: &'a ConfigStore,
    replacement: &'a ConfigSet,
}

impl Executor for SwapOnce<'_> {
    fn execute(&mut self, request: &Request, config: &Config) -> ExecOutcome {
        if self.executed.fetch_add(1, Ordering::SeqCst) + 1 == self.at {
            self.store.swap(self.replacement.clone());
        }
        self.inner.execute(request, config)
    }
}

fn swap_cell(
    ctx: &Ctx,
    set: &ConfigSet,
    spec: &FleetSpec,
    worlds: &[Testbed],
    tl: &[TimedRequest],
    seed: u64,
) -> (Vec<u64>, bool) {
    let net = Network::Vgg16;
    let n = tl.len().min(20_000);
    let tl = &tl[..n];
    // a second search gives the replacement front a distinct identity
    let mut solver = Solver::new(&ctx.testbed, net);
    solver.batch_per_trial = 60;
    let replacement = ConfigSet::new(solver.run(Strategy::NsgaIII, 120, seed + 1).pareto);

    let store = ConfigStore::new(set.clone());
    let stores = StoreMap::single(net, &store);
    let cfg = PipelineConfig {
        workers: 4,
        queue_capacity: QUEUE_PER_SHARD,
        max_batch: 4,
        time_scale: 0.0,
        seed,
        reuse: true,
        shards: 8,
        discrete: true,
    };
    let executed = AtomicUsize::new(0);
    let at = (n / 20).max(10);
    let report = run_pipeline_stores(&stores, &PaperPolicy, tl, &cfg, None, None, |_| {
        Ok(SwapOnce {
            inner: FleetExecutor { spec, worlds, stream: EXEC_STREAM },
            executed: &executed,
            at,
            store: &store,
            replacement: &replacement,
        })
    })
    .expect("scale swap cell");

    assert_eq!(report.records.len(), n, "swap cell: request conservation");
    reconcile(&report);
    let registry = store.epochs();
    for r in &report.records {
        if let ServeOutcome::Done { epoch, store_digest, .. } = &r.outcome {
            assert!(
                registry.contains(&(*epoch, *store_digest)),
                "request {} stamped an unregistered (epoch, digest) — torn store",
                r.request_id
            );
        }
    }
    let epochs = report.epochs_observed();
    assert_eq!(epochs, vec![0, 1], "the swap landed mid-replay");
    (epochs, true)
}

pub fn print_report(exp: &ScaleExperiment) {
    println!(
        "\n== fleet-scale serving — {} ({} requests, {} devices, {:.0} s simulated, \
         discrete-event clock) ==",
        exp.net.name(),
        exp.requests,
        exp.devices,
        exp.horizon_ms / 1000.0
    );
    let mut t = Table::new([
        "shards", "workers", "done", "expired", "shed", "QoS hit", "p50", "p99", "peak q",
        "wall", "req/s (wall)", "speedup",
    ]);
    for cell in &exp.cells {
        let r = &cell.report;
        t.row([
            cell.shards.to_string(),
            cell.workers.to_string(),
            r.completed().to_string(),
            r.expired_in_queue().to_string(),
            r.rejected_queue_full().to_string(),
            format!("{:.0}%", r.qos_hit_rate() * 100.0),
            format!("{:.0} ms", r.latency_p50()),
            format!("{:.0} ms", r.latency_p99()),
            r.queue.peak_depth.to_string(),
            format!("{:.2} s", r.wall_ms / 1000.0),
            format!("{:.0}", cell.wall_throughput()),
            format!("{:.0}x", exp.horizon_ms / r.wall_ms.max(1e-9)),
        ]);
    }
    t.print();
    println!(
        "per-shard slices reconcile exactly with the aggregates in every cell \
         (asserted during the run); speedup = simulated horizon / wall clock."
    );
    println!(
        "hot-swap cell (8 shards): store epochs observed {:?}; every completion's \
         (epoch, digest) stamp was a registered installation — torn-free: {}",
        exp.epochs_observed, exp.epochs_torn_free
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    fn experiment() -> ScaleExperiment {
        run(&Ctx::synthetic(), 600, 96, 17)
    }

    #[test]
    fn sweep_conserves_every_request_in_every_cell() {
        let exp = experiment();
        assert_eq!(exp.cells.len(), GRID.len());
        for cell in &exp.cells {
            assert_eq!(cell.report.records.len(), 600, "s{} w{}", cell.shards, cell.workers);
            assert_eq!(cell.report.shards, cell.shards);
            reconcile(&cell.report); // idempotent re-check outside run()
            assert!(cell.report.completed() > 0, "overload never starves completions");
        }
    }

    #[test]
    fn sharded_cells_actually_partition_traffic() {
        let exp = experiment();
        for cell in exp.cells.iter().filter(|c| c.shards > 1) {
            let populated = cell
                .report
                .shard_breakdown()
                .iter()
                .filter(|b| b.requests > 0)
                .count();
            assert!(populated > 1, "s{}: routing left every request on one shard", cell.shards);
        }
    }

    #[test]
    fn discrete_clock_replays_faster_than_real_time() {
        let exp = experiment();
        // ~600 requests at ~12/s ≈ 50 simulated seconds; the replay
        // must beat the trace horizon by a wide margin
        assert!(exp.horizon_ms > 10_000.0, "trace spans real seconds: {}", exp.horizon_ms);
        for cell in &exp.cells {
            assert!(
                cell.report.wall_ms < exp.horizon_ms,
                "s{} w{}: replay slower than real time ({} ms wall vs {} ms simulated)",
                cell.shards,
                cell.workers,
                cell.report.wall_ms,
                exp.horizon_ms
            );
        }
    }

    #[test]
    fn hot_swap_under_sharded_replay_is_torn_free() {
        let exp = experiment();
        assert!(exp.epochs_torn_free);
        assert_eq!(exp.epochs_observed, vec![0, 1]);
    }

    #[test]
    fn report_prints() {
        print_report(&experiment());
    }
}
