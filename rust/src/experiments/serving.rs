//! Serving-pipeline throughput experiment (beyond-paper; ROADMAP
//! "production-scale serving" north star).
//!
//! Sweeps the three scheduling policies × worker counts over one bursty
//! open-loop workload and reports the serving headline numbers: QoS
//! hit-rate, p50/p99 latency, energy per request, reconfigurations
//! (and how many the config-reuse cache avoided), and throughput.  A
//! final cache-off row under the paper policy isolates what config
//! reuse buys.

use crate::controller::{
    EnergyBudgetPolicy, PaperPolicy, PerRequestSimExecutor, SchedulingPolicy,
    StrictDeadlinePolicy,
};
use crate::controller::policy::ConfigSet;
use crate::serve::{run_pipeline, PipelineConfig, ServeReport};
use crate::solver::{Solver, Strategy};
use crate::space::Network;
use crate::util::rng::Pcg32;
use crate::util::stats;
use crate::util::table::Table;
use crate::workload::{timeline, ArrivalProcess, TimedRequest, WorkloadGen};

use super::Ctx;

/// One pipeline run under a (policy, workers, cache) combination.
#[derive(Debug, Clone)]
pub struct Row {
    pub policy: &'static str,
    pub workers: usize,
    pub reuse: bool,
    pub report: ServeReport,
}

#[derive(Debug, Clone)]
pub struct ServingExperiment {
    pub net: Network,
    pub requests: usize,
    pub rows: Vec<Row>,
}

/// Executor stream selector shared by every run: outcomes must depend
/// only on the request so rows are comparable across worker counts.
const EXEC_STREAM: u64 = 7001;

pub fn run(ctx: &Ctx, net: Network, requests: usize, seed: u64) -> ServingExperiment {
    // offline phase: a paper-sized 20%-budget search
    let mut solver = Solver::new(&ctx.testbed, net);
    solver.batch_per_trial = 60;
    let pareto = solver.run(Strategy::NsgaIII, 120, seed).pareto;
    let budget_j = stats::median(&pareto.iter().map(|e| e.energy_j).collect::<Vec<_>>());
    let set = ConfigSet::new(pareto);

    // one shared bursty workload (flash crowds stress the queue)
    let mut gen = WorkloadGen::paper(net);
    gen.inferences_per_request = 200;
    let mut rng = Pcg32::new(seed, 141);
    let process =
        ArrivalProcess::Bursty { base_rate_per_s: 100.0, period_s: 1.0, burst_size: 20 };
    let tl: Vec<TimedRequest> = timeline(&gen, &process, requests, &mut rng);

    let paper = PaperPolicy;
    let strict = StrictDeadlinePolicy;
    let budget = EnergyBudgetPolicy { budget_j };
    let policies: [(&'static str, &dyn SchedulingPolicy); 3] =
        [("paper", &paper), ("strict", &strict), ("budget", &budget)];

    let mut rows = Vec::new();
    let mut launch = |policy_name: &'static str,
                      policy: &dyn SchedulingPolicy,
                      workers: usize,
                      reuse: bool| {
        let cfg = PipelineConfig {
            workers,
            queue_capacity: requests.max(64),
            max_batch: 4,
            time_scale: 0.0,
            seed,
            reuse,
            ..PipelineConfig::default()
        };
        let report = run_pipeline(&set, policy, &tl, &cfg, |_| {
            Ok(PerRequestSimExecutor { testbed: &ctx.testbed, stream: EXEC_STREAM })
        })
        .expect("serving pipeline run");
        rows.push(Row { policy: policy_name, workers, reuse, report });
    };
    for (name, policy) in policies {
        for workers in [1, 2, 4] {
            launch(name, policy, workers, true);
        }
    }
    // cache-off baseline: what does config reuse buy?
    launch("paper", &paper, 2, false);

    ServingExperiment { net, requests, rows }
}

pub fn print_report(exp: &ServingExperiment) {
    println!(
        "\n== serving pipeline throughput — {} ({} requests, bursty open-loop) ==",
        exp.net.name(),
        exp.requests
    );
    let mut t = Table::new([
        "policy", "workers", "cache", "done", "shed", "rejected", "QoS hit", "p50", "p99",
        "J/req", "reconfigs", "avoided",
    ]);
    for row in &exp.rows {
        let r = &row.report;
        t.row([
            row.policy.to_string(),
            row.workers.to_string(),
            if row.reuse { "on" } else { "off" }.to_string(),
            r.completed().to_string(),
            r.rejected_queue_full().to_string(),
            r.rejected_by_policy().to_string(),
            format!("{:.0}%", r.qos_hit_rate() * 100.0),
            format!("{:.0} ms", r.latency_p50()),
            format!("{:.0} ms", r.latency_p99()),
            format!("{:.2}", r.mean_energy_j()),
            r.cache.reconfigs.to_string(),
            r.cache.hits.to_string(),
        ]);
    }
    t.print();
    println!(
        "per-request results are worker-count invariant (order-independent executors); \
         the cache-off row shows every request paying reconfiguration."
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    fn experiment() -> ServingExperiment {
        run(&Ctx::synthetic(), Network::Vgg16, 60, 17)
    }

    #[test]
    fn sweep_covers_policies_workers_and_cache_baseline() {
        let exp = experiment();
        assert_eq!(exp.rows.len(), 10, "3 policies x 3 worker counts + cache-off");
        for row in &exp.rows {
            assert_eq!(row.report.records.len(), 60, "{}: every request accounted", row.policy);
        }
        // the paper policy admits everything (queue sized to the workload)
        for row in exp.rows.iter().filter(|r| r.policy == "paper") {
            assert_eq!(row.report.completed(), 60);
        }
    }

    #[test]
    fn paper_rows_agree_across_worker_counts() {
        let exp = experiment();
        let paper: Vec<&Row> = exp
            .rows
            .iter()
            .filter(|r| r.policy == "paper" && r.reuse)
            .collect();
        assert_eq!(paper.len(), 3);
        // identical per-request outcomes -> identical energy and QoS rate
        let e0 = paper[0].report.mean_energy_j();
        let q0 = paper[0].report.qos_hit_rate();
        for row in &paper[1..] {
            assert_eq!(row.report.mean_energy_j(), e0);
            assert_eq!(row.report.qos_hit_rate(), q0);
        }
    }

    #[test]
    fn cache_accounting_identities_hold() {
        let exp = experiment();
        // every activation is either a reconfiguration or an avoided one,
        // and exactly one activation leads each coalesced batch
        for row in &exp.rows {
            let batches = row.report.completed() - row.report.coalesced();
            assert_eq!(
                row.report.cache.reconfigs + row.report.cache.hits,
                batches,
                "{} w{} cache {}",
                row.policy,
                row.workers,
                row.reuse
            );
        }
        // cache off: every batch pays a reconfiguration, nothing avoided
        let off = exp.rows.iter().find(|r| !r.reuse).expect("cache-off row");
        assert_eq!(off.report.cache.hits, 0);
        assert_eq!(
            off.report.cache.reconfigs,
            off.report.completed() - off.report.coalesced()
        );
    }

    #[test]
    fn report_prints() {
        print_report(&experiment());
    }
}
