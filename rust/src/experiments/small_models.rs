//! Finding (i) of the preliminary study (§2.2): small, mobile-optimized
//! models (ResNet50-mini, MobileNetV2-mini) do **not** benefit from
//! split computing — their edge-only execution is fast and frugal enough
//! that no split/cloud configuration improves on it, whereas the large
//! models (VGG16, ViT) clearly do.  This is why the paper's main
//! evaluation keeps only VGG16 and ViT.

use crate::model::small::SmallNetCost;
use crate::model::NetCost;
use crate::simulator::calib;
use crate::space::Network;
use crate::util::table::Table;

/// Latency/energy of one network at its three canonical placements
/// (edge-only / best split / cloud-only), all at max CPU frequency.
#[derive(Debug, Clone)]
pub struct PlacementProfile {
    pub name: String,
    pub edge_ms: f64,
    pub edge_j: f64,
    pub best_split_ms: f64,
    pub best_split_k: usize,
    pub cloud_ms: f64,
    pub cloud_j: f64,
    /// Does any split/cloud placement beat edge-only latency by > 10%?
    /// (§2.2's criterion is latency: the large models "demonstrated
    /// substantial improvements in latency when utilizing both edge and
    /// cloud resources"; the small ones did not)
    pub benefits_from_split: bool,
}

/// Analytic placement profile for a *small* model (simulator-level; the
/// small models have no artifacts — see model::small).
pub fn profile_small(c: &SmallNetCost) -> PlacementProfile {
    let l = c.layers.len();
    let edge_rate = c.total_macs() as f64 / c.edge_full_fp32_s;
    let gpu_rate = c.total_macs() as f64 / c.cloud_full_gpu_s;
    let lat = |k: usize| -> f64 {
        let head: u64 = c.layers[..k].iter().map(|x| x.macs).sum();
        let tail: u64 = c.layers[k..].iter().map(|x| x.macs).sum();
        let mut t = 0.005 + head as f64 / edge_rate; // prep + head
        if k < l {
            let bytes = c.transfer_bytes(k) + 40;
            t += calib::LINK_RTT_S + bytes as f64 / calib::LINK_BYTES_PER_S;
            t += 0.004 + tail as f64 / gpu_rate;
        }
        t
    };
    let edge_s = lat(l);
    let cloud_s = lat(0);
    let (best_k, best_s) = (1..l)
        .map(|k| (k, lat(k)))
        .min_by(|a, b| a.1.total_cmp(&b.1))
        .unwrap();
    // energy: edge busy during head, idle during net+cloud; cloud window.
    let energy = |k: usize| -> f64 {
        let head: u64 = c.layers[..k].iter().map(|x| x.macs).sum();
        let head_s = head as f64 / edge_rate;
        let total_s = lat(k);
        let busy_p = calib::EDGE_IDLE_W + calib::EDGE_CPU_CUBIC_W_PER_GHZ3 * 1.8f64.powi(3);
        let mut e = busy_p * head_s + calib::EDGE_IDLE_W * (total_s - head_s - 0.005).max(0.0);
        if k < l {
            let tail: u64 = c.layers[k..].iter().map(|x| x.macs).sum();
            e += calib::CLOUD_GPU_ACTIVE_W * (tail as f64 / gpu_rate);
        }
        e
    };
    let edge_j = energy(l);
    let cloud_j = energy(0);
    let beats = |ms: f64| ms < 0.9 * edge_s * 1000.0;
    PlacementProfile {
        name: c.name.to_string(),
        edge_ms: edge_s * 1000.0,
        edge_j,
        best_split_ms: best_s * 1000.0,
        best_split_k: best_k,
        cloud_ms: cloud_s * 1000.0,
        cloud_j,
        benefits_from_split: beats(best_s * 1000.0) || beats(cloud_s * 1000.0),
    }
}

/// Placement profile for a *large* (main-evaluation) network via the full
/// device model.
pub fn profile_large(net: Network) -> PlacementProfile {
    let dm = crate::simulator::device::DeviceModel::new(NetCost::of(net));
    let l = net.num_layers();
    let cfg = |k: usize| {
        crate::space::feasible::repair(crate::space::Config {
            net,
            cpu_idx: 6,
            tpu: crate::space::TpuMode::Off,
            gpu: true,
            split: k,
        })
    };
    let lat = |k: usize| dm.latency(&cfg(k)).total_s() * 1000.0;
    let energy = |k: usize| {
        let b = dm.latency(&cfg(k));
        let busy = crate::simulator::power::edge_power(
            crate::simulator::power::EdgeState::CpuBusy,
            &cfg(k),
        );
        let idle = crate::simulator::power::edge_power(
            crate::simulator::power::EdgeState::Idle,
            &cfg(k),
        );
        busy * b.edge_s
            + idle * (b.net_s + b.cloud_s)
            + if k < l { crate::simulator::power::cloud_power(&cfg(k)) * b.cloud_s } else { 0.0 }
    };
    let (best_k, best_ms) = (1..l)
        .map(|k| (k, lat(k)))
        .min_by(|a, b| a.1.total_cmp(&b.1))
        .unwrap();
    let edge_ms = lat(l);
    let edge_j = energy(l);
    let cloud_ms = lat(0);
    let beats = |ms: f64| ms < 0.9 * edge_ms;
    PlacementProfile {
        name: net.name().to_string(),
        edge_ms,
        edge_j,
        best_split_ms: best_ms,
        best_split_k: best_k,
        cloud_ms,
        cloud_j: energy(0),
        benefits_from_split: beats(best_ms) || beats(cloud_ms),
    }
}

/// Run the four-network §2.2 comparison.
pub fn run() -> Vec<PlacementProfile> {
    vec![
        profile_small(&crate::model::small::mobilenetv2_mini()),
        profile_small(&crate::model::small::resnet50_mini()),
        profile_large(Network::Vgg16),
        profile_large(Network::Vit),
    ]
}

pub fn print_report(profiles: &[PlacementProfile]) {
    println!("\n== §2.2 finding (i) — which networks benefit from split computing ==");
    let mut t = Table::new([
        "network", "edge-only", "edge J", "best split", "cloud-only", "benefits?",
    ]);
    for p in profiles {
        t.row([
            p.name.clone(),
            format!("{:.0} ms", p.edge_ms),
            format!("{:.1} J", p.edge_j),
            format!("{:.0} ms (k={})", p.best_split_ms, p.best_split_k),
            format!("{:.0} ms", p.cloud_ms),
            if p.benefits_from_split { "YES" } else { "no" }.to_string(),
        ]);
    }
    t.print();
    println!(
        "paper finding (i): ResNet50/MobileNetV2 gain nothing from split computing \
         (fast + frugal edge-only); VGG16/ViT gain substantially — which is why the \
         main evaluation keeps only the large networks."
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn finding_i_reproduces() {
        let profiles = run();
        let by_name = |n: &str| profiles.iter().find(|p| p.name == n).unwrap();
        assert!(!by_name("mobilenetv2").benefits_from_split, "{:?}", by_name("mobilenetv2"));
        assert!(!by_name("resnet50").benefits_from_split, "{:?}", by_name("resnet50"));
        assert!(by_name("vgg16").benefits_from_split, "{:?}", by_name("vgg16"));
        assert!(by_name("vit").benefits_from_split, "{:?}", by_name("vit"));
    }

    #[test]
    fn small_models_run_fast_on_edge() {
        for p in run() {
            if p.name == "mobilenetv2" || p.name == "resnet50" {
                assert!(p.edge_ms < 250.0, "{}: {}", p.name, p.edge_ms);
                assert!(p.edge_j < 2.0, "{}: {}", p.name, p.edge_j);
            }
        }
    }

    #[test]
    fn report_prints() {
        print_report(&run());
    }
}
