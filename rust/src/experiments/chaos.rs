//! Chaos-serving experiment (beyond-paper; DESIGN.md §15).
//!
//! Replays one mixed cloud/edge Pareto front through the serving
//! pipeline under three seeded fault scenarios —
//!
//! * **link flap** — the edge–cloud link drops periodically, plus a
//!   per-attempt frame-loss rate while it is up;
//! * **tail brownout** — the serving device browns out late in the
//!   trace, plus transient executor stalls throughout;
//! * **shard outage** — one of four admission shards fails for the
//!   middle of the trace (correlated, device-local);
//!
//! — and compares three recovery modes per scenario: **no recovery**
//! (legacy one-shot shed), **retry-only** (deadline-budgeted retries,
//! [`RetryPolicy::budgeted`]), and **retry + breaker** (retries plus a
//! per-network circuit breaker that degrades scheduling to the
//! edge-only store view while open).  Every cell runs under both the
//! virtual and the discrete-event clock.
//!
//! The taxonomy does the storytelling: retries absorb *transient*
//! faults (loss, stalls) in every scenario; only the breaker survives
//! *persistent cloud-link* windows (degraded edge-only service at an
//! energy premium); and nothing dodges persistent *local* faults
//! (brownouts, shard outages) — the breaker correctly refuses to open
//! on them, because degradation would not help.
//!
//! Single-worker, single-request batches: every cell is bitwise
//! reproducible, asserted by running the flagship cell twice.

use crate::adapt::{ConfigStore, StoreMap};
use crate::controller::policy::ConfigSet;
use crate::controller::{ExecOutcome, Executor, PaperPolicy};
use crate::fault::{BreakerMap, BreakerState, FaultInjector, FaultPlan, ShardOutage};
use crate::serve::{
    run_pipeline_resilient, PipelineConfig, RetryPolicy, ServeReport,
};
use crate::solver::ParetoEntry;
use crate::space::{Config, Network, TpuMode};
use crate::util::table::Table;
use crate::workload::{Request, TimedRequest};

/// QoS budget shared by every request: generous against the healthy
/// latencies below, so misses are caused by faults, not provisioning.
const QOS_MS: f64 = 200.0;

/// Recovery modes under comparison.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Recovery {
    /// Legacy one-shot dispatch: a failed batch is shed.
    None,
    /// Deadline-budgeted retries, no breaker.
    RetryOnly,
    /// Retries plus the per-network circuit breaker (edge-only
    /// degradation while open).
    RetryBreaker,
}

impl Recovery {
    pub const ALL: [Recovery; 3] = [Recovery::None, Recovery::RetryOnly, Recovery::RetryBreaker];

    pub fn name(self) -> &'static str {
        match self {
            Recovery::None => "none",
            Recovery::RetryOnly => "retry",
            Recovery::RetryBreaker => "retry+breaker",
        }
    }
}

/// One (scenario, clock, recovery) pipeline replay.
pub struct ChaosCell {
    pub scenario: &'static str,
    pub clock: &'static str,
    pub recovery: Recovery,
    pub report: ServeReport,
    /// Breaker state when the run ended (`None` without a breaker).
    pub breaker_end: Option<BreakerState>,
}

pub struct ChaosExperiment {
    pub requests: usize,
    pub cells: Vec<ChaosCell>,
    /// The flagship (link-flap, virtual, retry+breaker) cell replayed
    /// bitwise identically under the same seed.
    pub deterministic: bool,
}

/// The mixed front: a fast cheap cloud config the policy prefers, and
/// an edge-only fallback ([`Config::is_edge_only`]) that survives link
/// faults at a latency/energy premium.
fn front(net: Network) -> ConfigSet {
    let entry = |split: usize, latency_ms: f64, energy_j: f64| ParetoEntry {
        config: Config { net, cpu_idx: 6, tpu: TpuMode::Off, gpu: true, split },
        latency_ms,
        energy_j,
        accuracy: 0.95,
    };
    ConfigSet::new(vec![
        entry(3, 45.0, 1.5),
        entry(net.num_layers(), 80.0, 5.0),
    ])
}

/// Deterministic split-path executor: outcome is a pure function of
/// `(request, config)` — cloud splits are fast and cheap, the edge-only
/// split slower and hungrier, mirroring the front's predictions.
struct SplitExec {
    net: Network,
}

impl Executor for SplitExec {
    fn execute(&mut self, request: &Request, config: &Config) -> ExecOutcome {
        let edge_only = config.split >= self.net.num_layers();
        let base = if edge_only { 80.0 } else { 45.0 };
        let energy = if edge_only { 5.0 } else { 1.5 };
        ExecOutcome {
            latency_ms: base + (request.seed % 7) as f64,
            energy_j: energy,
            edge_energy_j: if edge_only { energy } else { 0.5 },
            cloud_energy_j: if edge_only { 0.0 } else { energy - 0.5 },
            accuracy: 0.95,
        }
    }
}

fn timeline(net: Network, requests: usize) -> Vec<TimedRequest> {
    (0..requests)
        .map(|i| TimedRequest {
            request: Request {
                id: i,
                net,
                qos_ms: QOS_MS,
                inferences: 1,
                seed: i as u64,
            },
            // 100 ms gaps: a single worker keeps up even with retry
            // penalties, so discrete-clock cells measure fault impact,
            // not self-inflicted queueing collapse.  Fault windows key
            // on nominal id-time (id_ms = 1), not on this gap.
            arrival_ms: i as f64 * 100.0,
        })
        .collect()
}

/// The three scenario schedules, all in nominal id-time (`id_ms = 1`:
/// request *id* is the time axis, independent of [`timeline`]'s
/// arrival pacing — the same ids fault under either clock).
fn scenarios(requests: usize, seed: u64) -> Vec<(&'static str, FaultPlan, usize)> {
    let horizon = requests as f64;
    // link flap: down 20 ms of every 60 ms, 20% frame loss while up
    let mut flap = FaultPlan::link_flap(seed, 1.0, 60.0, 20.0, horizon);
    flap.loss_p = 0.2;
    // tail brownout: the device browns out for the trace's last
    // quarter; transient stalls throughout
    let brownout = FaultPlan {
        seed: seed ^ 0xb0,
        id_ms: 1.0,
        brownout: vec![(horizon * 0.75, horizon)],
        stall_p: 0.2,
        ..FaultPlan::none()
    };
    // shard outage: one of four shards dark for the middle half
    let outage = FaultPlan {
        seed: seed ^ 0x5d,
        id_ms: 1.0,
        shard_down: Some(ShardOutage {
            shard: 1,
            shards: 4,
            window: (horizon * 0.25, horizon * 0.75),
        }),
        stall_p: 0.1,
        ..FaultPlan::none()
    };
    vec![("link flap", flap, 1), ("tail brownout", brownout, 1), ("shard outage", outage, 4)]
}

fn run_cell(
    net: Network,
    set: &ConfigSet,
    tl: &[TimedRequest],
    plan: &FaultPlan,
    shards: usize,
    discrete: bool,
    recovery: Recovery,
    seed: u64,
) -> ChaosCell {
    let store = ConfigStore::new(set.clone());
    let stores = StoreMap::single(net, &store);
    let cfg = PipelineConfig {
        workers: 1,
        queue_capacity: tl.len().max(16),
        max_batch: 1,
        time_scale: 0.0,
        seed,
        reuse: true,
        shards,
        discrete,
    };
    let retry = match recovery {
        Recovery::None => RetryPolicy::none(),
        Recovery::RetryOnly | Recovery::RetryBreaker => RetryPolicy::budgeted(),
    };
    let breakers = match recovery {
        Recovery::RetryBreaker => Some(BreakerMap::new(&[net], 3, 8)),
        _ => None,
    };
    let report = run_pipeline_resilient(
        &stores,
        &PaperPolicy,
        tl,
        &cfg,
        None,
        None,
        retry,
        breakers.as_ref(),
        &crate::obs::OFF,
        |_| Ok(FaultInjector::new(SplitExec { net }, plan.clone())),
    )
    .expect("chaos cell run");

    // hard invariants, re-checked in every cell: no request lost, and
    // every degraded completion is a real edge-only config resolved
    // against a registered (epoch, digest) installation
    assert_eq!(report.records.len(), tl.len(), "request conservation");
    let registry = store.epochs();
    for r in &report.records {
        if let Some(c) = r.outcome.completion() {
            if c.degraded {
                assert!(c.config.is_edge_only(), "degraded request {} left the edge", r.request_id);
            }
            assert!(
                registry.contains(&(c.epoch, c.store_digest)),
                "request {} stamped an unregistered (epoch, digest)",
                r.request_id
            );
        }
    }
    ChaosCell {
        scenario: "",
        clock: if discrete { "discrete" } else { "virtual" },
        recovery,
        report,
        breaker_end: breakers.as_ref().and_then(|b| b.state(net)),
    }
}

pub fn run(requests: usize, seed: u64) -> ChaosExperiment {
    let net = Network::Vgg16;
    let set = front(net);
    let tl = timeline(net, requests);
    let mut cells = Vec::new();
    for (name, plan, shards) in scenarios(requests, seed) {
        for discrete in [false, true] {
            for recovery in Recovery::ALL {
                let mut cell =
                    run_cell(net, &set, &tl, &plan, shards, discrete, recovery, seed);
                cell.scenario = name;
                cells.push(cell);
            }
        }
    }

    // determinism: replay the flagship cell and demand bitwise-equal
    // per-request records and aggregates (wall-clock throughput is the
    // one legitimately non-reproducible report field)
    let (_, flap, _) = &scenarios(requests, seed)[0];
    let a = run_cell(net, &set, &tl, flap, 1, false, Recovery::RetryBreaker, seed);
    let b = run_cell(net, &set, &tl, flap, 1, false, Recovery::RetryBreaker, seed);
    let aggregates = |r: &ServeReport| {
        (
            r.completed(),
            r.retried(),
            r.degraded_served(),
            r.retry_failed(),
            r.qos_hit_rate().to_bits(),
            r.mean_energy_j().to_bits(),
        )
    };
    let deterministic = aggregates(&a.report) == aggregates(&b.report)
        && format!("{:?}", a.report.records) == format!("{:?}", b.report.records);

    ChaosExperiment { requests, cells, deterministic }
}

pub fn print_report(exp: &ChaosExperiment) {
    println!(
        "\n== chaos serving — vgg16, {} requests per cell, QoS {:.0} ms (DESIGN.md §15) ==",
        exp.requests, QOS_MS
    );
    let mut t = Table::new([
        "scenario", "clock", "recovery", "done", "failed", "expired", "retried", "degraded",
        "QoS hit", "J/req", "breaker",
    ]);
    for cell in &exp.cells {
        let r = &cell.report;
        t.row([
            cell.scenario.to_string(),
            cell.clock.to_string(),
            cell.recovery.name().to_string(),
            r.completed().to_string(),
            (r.executor_failed() + r.retry_failed()).to_string(),
            r.expired_in_queue().to_string(),
            r.retried().to_string(),
            r.degraded_served().to_string(),
            format!("{:.0}%", r.qos_hit_rate() * 100.0),
            if r.completed() > 0 { format!("{:.2}", r.mean_energy_j()) } else { "-".into() },
            cell.breaker_end.map_or("-".to_string(), |s| format!("{s:?}")),
        ]);
    }
    t.print();
    println!(
        "retries absorb transient faults; the breaker alone survives persistent link windows \
         (edge-only degradation, note the J/req premium); persistent local faults (brownout, \
         shard outage) defeat both — the breaker correctly never opens on them."
    );
    println!(
        "identically-seeded flagship cells replay bitwise-identically: {}",
        exp.deterministic
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    fn experiment() -> ChaosExperiment {
        run(240, 11)
    }

    fn qos(exp: &ChaosExperiment, scenario: &str, clock: &str, recovery: Recovery) -> f64 {
        exp.cells
            .iter()
            .find(|c| c.scenario == scenario && c.clock == clock && c.recovery == recovery)
            .expect("cell exists")
            .report
            .qos_hit_rate()
    }

    #[test]
    fn recovery_strictly_improves_the_link_flap_scenario() {
        let exp = experiment();
        for clock in ["virtual", "discrete"] {
            let none = qos(&exp, "link flap", clock, Recovery::None);
            let retry = qos(&exp, "link flap", clock, Recovery::RetryOnly);
            let breaker = qos(&exp, "link flap", clock, Recovery::RetryBreaker);
            assert!(retry > none, "{clock}: retries absorb frame loss ({retry} vs {none})");
            assert!(breaker > retry, "{clock}: degradation survives link windows ({breaker} vs {retry})");
        }
    }

    #[test]
    fn breaker_serves_degraded_requests_only_in_link_scenarios() {
        let exp = experiment();
        for cell in &exp.cells {
            if cell.recovery != Recovery::RetryBreaker {
                assert_eq!(cell.report.degraded_served(), 0, "{}", cell.scenario);
                continue;
            }
            match cell.scenario {
                "link flap" => assert!(
                    cell.report.degraded_served() > 0,
                    "{}: open breaker must degrade-serve",
                    cell.clock
                ),
                // local faults must never open the breaker
                _ => assert_eq!(
                    cell.report.degraded_served(),
                    0,
                    "{} ({}): breaker opened on a local fault",
                    cell.scenario,
                    cell.clock
                ),
            }
        }
    }

    #[test]
    fn degraded_service_costs_energy() {
        let exp = experiment();
        let cheap = exp
            .cells
            .iter()
            .find(|c| c.scenario == "link flap" && c.clock == "virtual" && c.recovery == Recovery::None)
            .unwrap();
        let degraded = exp
            .cells
            .iter()
            .find(|c| {
                c.scenario == "link flap"
                    && c.clock == "virtual"
                    && c.recovery == Recovery::RetryBreaker
            })
            .unwrap();
        assert!(
            degraded.report.mean_energy_j() > cheap.report.mean_energy_j(),
            "edge-only fallback pays the energy premium: {} vs {}",
            degraded.report.mean_energy_j(),
            cheap.report.mean_energy_j()
        );
    }

    #[test]
    fn flagship_cell_is_bitwise_deterministic() {
        assert!(experiment().deterministic);
    }

    #[test]
    fn report_prints() {
        print_report(&experiment());
    }
}
