//! Closed-loop adaptation experiment (beyond-paper; ROADMAP "Pareto
//! store hot-swap" + "closed-loop admission").
//!
//! Scenario: the world steps mid-run — the edge↔cloud link loses most
//! of its bandwidth and the edge thermally throttles — while the
//! serving pipeline keeps taking traffic.  A **control** run keeps the
//! offline Pareto store frozen (the paper's online phase): its
//! scheduler keeps trusting stale predictions, picking offloading
//! configurations whose real latency now blows the deadline.  The
//! **adaptive** run serves the same workload through
//! [`crate::adapt::run_closed_loop`]: telemetry sees measured latency
//! diverge from the store's predictions, drift detection flags the
//! sustained error, a calibrated warm-started re-solve produces a
//! fresh front, and the store hot-swaps under live traffic — QoS
//! recovers for every deadline the post-shift hardware can still meet.

use std::time::Duration;

use crate::adapt::{
    run_closed_loop, AdaptConfig, AdaptiveLoop, ClosedLoopReport, ConfigStore, DriftConfig,
    ResolveConfig, Telemetry,
};
use crate::controller::policy::ConfigSet;
use crate::controller::{ExecOutcome, Executor, PaperPolicy, PerRequestSimExecutor};
use crate::serve::{run_pipeline, PipelineConfig, ServeReport};
use crate::simulator::Testbed;
use crate::solver::{Solver, Strategy};
use crate::space::Network;
use crate::util::rng::Pcg32;
use crate::util::table::Table;
use crate::workload::{timeline, ArrivalProcess, Request, TimedRequest, WorkloadGen};

use super::Ctx;

/// Fork a drifted world from the calibrated base testbed: the link
/// keeps a fraction `bandwidth_factor` of its bandwidth at `rtt_factor`
/// times the RTT, and the edge runs at `edge_throttle` of its rate.
pub fn shifted_testbed(
    base: &Testbed,
    bandwidth_factor: f64,
    rtt_factor: f64,
    edge_throttle: f64,
) -> Testbed {
    let mut tb = base.clone();
    tb.link.bytes_per_s *= bandwidth_factor;
    tb.link.rtt_s *= rtt_factor;
    tb.vgg.throttle_edge(edge_throttle);
    tb.vit.throttle_edge(edge_throttle);
    tb
}

/// Order-independent executor over a world that steps at request
/// `shift_at`: requests with `id < shift_at` sample the base testbed,
/// later ones the shifted testbed.  Keying on the request id keeps
/// outcomes a pure function of `(request, config)` — the pipeline's
/// order-independence contract — while modeling a timeline-positioned
/// shift (ids are arrival-ordered).  `floor` adds a deterministic
/// wall-clock service floor so the concurrent adaptation loop gets real
/// time to act mid-run.
pub struct ShiftExecutor<'tb> {
    pub base: PerRequestSimExecutor<'tb>,
    pub shifted: PerRequestSimExecutor<'tb>,
    pub shift_at: usize,
    pub floor: Duration,
}

impl<'tb> ShiftExecutor<'tb> {
    pub fn new(
        base: &'tb Testbed,
        shifted: &'tb Testbed,
        shift_at: usize,
        stream: u64,
        floor: Duration,
    ) -> ShiftExecutor<'tb> {
        ShiftExecutor {
            base: PerRequestSimExecutor { testbed: base, stream },
            shifted: PerRequestSimExecutor { testbed: shifted, stream },
            shift_at,
            floor,
        }
    }
}

impl Executor for ShiftExecutor<'_> {
    fn execute(&mut self, request: &Request, config: &crate::space::Config) -> ExecOutcome {
        if !self.floor.is_zero() {
            std::thread::sleep(self.floor);
        }
        if request.id < self.shift_at {
            self.base.execute(request, config)
        } else {
            self.shifted.execute(request, config)
        }
    }
}

/// Post-shift QoS hit rate of a report (the recovery metric: requests
/// that arrived into the drifted world).
pub fn post_shift_hit_rate(report: &ServeReport, shift_at: usize) -> f64 {
    let post: Vec<_> = report.records.iter().filter(|r| r.request_id >= shift_at).collect();
    let hits = post.iter().filter(|r| r.qos_met()).count();
    hits as f64 / post.len().max(1) as f64
}

pub struct AdaptationExperiment {
    pub net: Network,
    pub requests: usize,
    pub shift_at: usize,
    pub control: ServeReport,
    pub adaptive: ClosedLoopReport,
}

impl AdaptationExperiment {
    pub fn control_post_hit(&self) -> f64 {
        post_shift_hit_rate(&self.control, self.shift_at)
    }

    pub fn adaptive_post_hit(&self) -> f64 {
        post_shift_hit_rate(&self.adaptive.serve, self.shift_at)
    }
}

/// Run the mid-run-shift scenario: control (frozen store) vs adaptive
/// (closed loop) over the same workload, executors, and seed.
pub fn run(ctx: &Ctx, net: Network, requests: usize, seed: u64) -> AdaptationExperiment {
    // offline phase on the (still correct) base world
    let mut solver = Solver::new(&ctx.testbed, net);
    solver.batch_per_trial = 60;
    let pareto = solver.run(Strategy::NsgaIII, 120, seed).pareto;
    let set = ConfigSet::new(pareto);

    // the drifted world: 1/8 bandwidth, 4x RTT, 30% edge throttle
    let shifted = shifted_testbed(&ctx.testbed, 1.0 / 8.0, 4.0, 0.7);
    let shift_at = requests / 3;

    let mut gen = WorkloadGen::paper(net);
    gen.inferences_per_request = 200;
    let mut rng = Pcg32::new(seed, 191);
    let tl: Vec<TimedRequest> =
        timeline(&gen, &ArrivalProcess::Poisson { rate_per_s: 200.0 }, requests, &mut rng);

    let pipeline = PipelineConfig {
        workers: 2,
        queue_capacity: requests.max(64),
        max_batch: 4,
        time_scale: 0.0,
        seed,
        reuse: true,
        ..PipelineConfig::default()
    };
    // a small real-time service floor paces virtual-time serving so the
    // concurrent loop can detect + re-solve while traffic still flows
    let floor = Duration::from_micros(200);
    let factory = |_: usize| {
        Ok::<_, anyhow::Error>(ShiftExecutor::new(&ctx.testbed, &shifted, shift_at, 192, floor))
    };

    let control =
        run_pipeline(&set, &PaperPolicy, &tl, &pipeline, factory).expect("control run");

    let adapt_cfg = AdaptConfig {
        window: 24,
        drift: DriftConfig { rel_threshold: 0.3, consecutive_windows: 2, min_samples: 3 },
        resolve: ResolveConfig { trials: 48, batch_per_trial: 16, min_measured: 3, seed },
        poll_ms: 1,
        history: 192,
        max_swaps: 4,
        ..AdaptConfig::default()
    };
    let store = ConfigStore::new(set);
    let telemetry = Telemetry::new(pipeline.workers, adapt_cfg.telemetry_capacity);
    let adapt_loop = AdaptiveLoop::new(&store, &telemetry, &ctx.testbed, net, adapt_cfg);
    let adaptive = run_closed_loop(adapt_loop, &PaperPolicy, &tl, &pipeline, factory)
        .expect("adaptive run");

    AdaptationExperiment { net, requests, shift_at, control, adaptive }
}

pub fn print_report(exp: &AdaptationExperiment) {
    println!(
        "\n== closed-loop adaptation — {} ({} requests, world steps at request {}: \
         bandwidth /8, RTT x4, edge throttled to 70%) ==",
        exp.net.name(),
        exp.requests,
        exp.shift_at
    );
    let mut t = Table::new(["run", "QoS hit (all)", "QoS hit (post-shift)", "done", "epochs"]);
    for (name, report, epochs) in [
        ("control (frozen store)", &exp.control, 1usize),
        (
            "adaptive (closed loop)",
            &exp.adaptive.serve,
            exp.adaptive.epochs.len(),
        ),
    ] {
        t.row([
            name.to_string(),
            format!("{:.0}%", report.qos_hit_rate() * 100.0),
            format!("{:.0}%", post_shift_hit_rate(report, exp.shift_at) * 100.0),
            report.completed().to_string(),
            epochs.to_string(),
        ]);
    }
    t.print();
    let a = &exp.adaptive.adapt;
    println!(
        "adaptation loop: {} samples, {} windows, {} drift events, {} re-solves, {} hot-swaps",
        a.samples, a.windows, a.drift_events, a.resolves, a.swaps
    );
    println!(
        "recovery: post-shift QoS {:.0}% -> {:.0}% (drift detected from measured-vs-predicted \
         telemetry; re-solve warm-started from the live front; store swapped under traffic)",
        exp.control_post_hit() * 100.0,
        exp.adaptive_post_hit() * 100.0
    );
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::ServeOutcome;

    fn experiment() -> AdaptationExperiment {
        run(&Ctx::synthetic(), Network::Vgg16, 360, 23)
    }

    #[test]
    fn shifted_testbed_slows_offloading_configs() {
        let base = Testbed::synthetic();
        let shifted = shifted_testbed(&base, 1.0 / 8.0, 4.0, 0.7);
        let mut rng_a = Pcg32::seeded(1);
        let mut rng_b = Pcg32::seeded(1);
        let space = crate::space::Space::new(Network::Vgg16);
        let cloudish = crate::space::feasible::repair(space.decode(&[6, 0, 1, 0]));
        let a = base.run_trial_n(&cloudish, 60, &mut rng_a);
        let b = shifted.run_trial_n(&cloudish, 60, &mut rng_b);
        assert!(
            b.latency_ms > a.latency_ms * 1.5,
            "bandwidth collapse must slow cloud-only: {} vs {}",
            b.latency_ms,
            a.latency_ms
        );
        // edge-only also slows (throttle), but far less than offloading
        let edgeish = crate::space::feasible::repair(space.decode(&[6, 2, 0, 22]));
        let ea = base.run_trial_n(&edgeish, 60, &mut Pcg32::seeded(2));
        let eb = shifted.run_trial_n(&edgeish, 60, &mut Pcg32::seeded(2));
        assert!(eb.latency_ms > ea.latency_ms, "throttle slows the edge");
        assert!(
            eb.latency_ms / ea.latency_ms < b.latency_ms / a.latency_ms,
            "offloading hurts more than edge under a bandwidth collapse"
        );
    }

    #[test]
    fn closed_loop_bookkeeping_and_epoch_coherence_under_live_traffic() {
        let exp = experiment();
        // every request accounted for, in both runs
        assert_eq!(exp.control.records.len(), 360);
        assert_eq!(exp.adaptive.serve.records.len(), 360);
        // the loop saw telemetry and sealed windows
        assert!(exp.adaptive.adapt.samples > 0, "telemetry flowed");
        assert!(exp.adaptive.adapt.windows > 0, "windows sealed");
        // epoch coherence: every completed request's (epoch, digest) is
        // a registered installation — no request saw a torn store
        let epochs = &exp.adaptive.epochs;
        for r in &exp.adaptive.serve.records {
            if let ServeOutcome::Done { epoch, store_digest, .. } = &r.outcome {
                assert!(
                    epochs.contains(&(*epoch, *store_digest)),
                    "request {} stamped unregistered (epoch, digest)",
                    r.request_id
                );
            }
        }
        // the sustained shift must be detected and acted on mid-run
        assert!(
            exp.adaptive.adapt.swaps >= 1,
            "drift -> re-solve -> swap never fired: {:?}",
            exp.adaptive.adapt
        );
        assert!(epochs.len() >= 2);
        // and adaptation never does *worse* than the frozen store
        assert!(
            exp.adaptive_post_hit() >= exp.control_post_hit() - 1e-9,
            "adaptive {} vs control {}",
            exp.adaptive_post_hit(),
            exp.control_post_hit()
        );
    }

    #[test]
    fn report_prints() {
        print_report(&experiment());
    }
}
