//! Fig. 2 — the preliminary study (§2.2): the impact of each knob on
//! VGG16 latency / energy / accuracy, averaged over many inferences.

use super::Ctx;
use crate::space::{Config, Network, TpuMode};
use crate::util::rng::Pcg32;
use crate::util::table::Table;

/// One sweep point: configuration + averaged metrics.
#[derive(Debug, Clone)]
pub struct SweepPoint {
    pub label: String,
    pub latency_ms: f64,
    pub energy_j: f64,
    pub accuracy: f64,
}

/// All five Fig. 2 panels.
#[derive(Debug, Clone)]
pub struct PrelimResult {
    pub fig2a_cpu_freq: Vec<SweepPoint>,
    pub fig2b_split: Vec<SweepPoint>,
    pub fig2c_tpu: Vec<SweepPoint>,
    pub fig2d_gpu: Vec<SweepPoint>,
    pub fig2e_accuracy: Vec<SweepPoint>,
}

fn cfg(cpu_idx: usize, tpu: TpuMode, gpu: bool, split: usize) -> Config {
    crate::space::feasible::repair(Config { net: Network::Vgg16, cpu_idx, tpu, gpu, split })
}

/// Run the preliminary study (batch inferences per point like the paper's
/// 1,000-inference averages; `batch` shrinks it for tests).
pub fn run(ctx: &Ctx, batch: usize, seed: u64) -> PrelimResult {
    let mut rng = Pcg32::new(seed, 21);
    let mut point = |label: String, c: &Config| {
        let t = ctx.testbed.run_trial_n(c, batch, &mut rng);
        SweepPoint { label, latency_ms: t.latency_ms, energy_j: t.energy_j, accuracy: t.accuracy }
    };

    // Fig. 2a: edge-only, TPU off, CPU frequency sweep.
    let fig2a = (0..crate::space::CPU_FREQS_GHZ.len())
        .map(|i| point(format!("{:.1} GHz", crate::space::CPU_FREQS_GHZ[i]), &cfg(i, TpuMode::Off, false, 22)))
        .collect();

    // Fig. 2b: split sweep with TPU max, CPU 1.8 GHz, cloud GPU.
    let fig2b = (0..=22)
        .map(|k| point(format!("split {k}"), &cfg(6, TpuMode::Max, true, k)))
        .collect();

    // Fig. 2c: edge acceleration off/std/max (edge-only, CPU 1.8).
    let fig2c = TpuMode::ALL
        .iter()
        .map(|&m| point(m.label().to_string(), &cfg(6, m, false, 22)))
        .collect();

    // Fig. 2d: cloud GPU on/off (cloud-only, CPU 1.8).
    let fig2d = [false, true]
        .iter()
        .map(|&g| point(if g { "GPU" } else { "no GPU" }.to_string(), &cfg(6, TpuMode::Off, g, 0)))
        .collect();

    // Fig. 2e: accuracy vs split, TPU (int8 head) vs CPU (fp32).
    let mut fig2e = Vec::new();
    for k in 0..=22 {
        let tpu = ctx.testbed.accuracy.accuracy(&cfg(6, TpuMode::Max, true, k));
        let cpu = ctx.testbed.accuracy.accuracy(&cfg(6, TpuMode::Off, true, k));
        fig2e.push(SweepPoint {
            label: format!("split {k} tpu"),
            latency_ms: 0.0,
            energy_j: 0.0,
            accuracy: tpu,
        });
        fig2e.push(SweepPoint {
            label: format!("split {k} cpu"),
            latency_ms: 0.0,
            energy_j: 0.0,
            accuracy: cpu,
        });
    }

    PrelimResult {
        fig2a_cpu_freq: fig2a,
        fig2b_split: fig2b,
        fig2c_tpu: fig2c,
        fig2d_gpu: fig2d,
        fig2e_accuracy: fig2e,
    }
}

pub fn print_report(r: &PrelimResult) {
    println!("\n== Fig. 2a — edge-only latency/energy vs CPU frequency (VGG16, TPU off) ==");
    let mut t = Table::new(["CPU freq", "latency", "energy"]);
    for p in &r.fig2a_cpu_freq {
        t.row([p.label.clone(), format!("{:.0} ms", p.latency_ms), format!("{:.2} J", p.energy_j)]);
    }
    t.print();
    println!("paper shape: both fall as frequency rises; energy flattens at the top; outliers at 0.8 GHz.");

    println!("\n== Fig. 2b — latency/energy vs split layer (TPU max, CPU 1.8, GPU) ==");
    let mut t = Table::new(["split", "latency", "energy"]);
    for p in &r.fig2b_split {
        t.row([p.label.clone(), format!("{:.0} ms", p.latency_ms), format!("{:.2} J", p.energy_j)]);
    }
    t.print();
    println!("paper shape: non-monotone; latency and energy track each other.");

    println!("\n== Fig. 2c — edge acceleration (edge-only) ==");
    let mut t = Table::new(["TPU", "latency", "energy"]);
    for p in &r.fig2c_tpu {
        t.row([p.label.clone(), format!("{:.0} ms", p.latency_ms), format!("{:.2} J", p.energy_j)]);
    }
    t.print();
    let off = &r.fig2c_tpu[0];
    let max = &r.fig2c_tpu[2];
    println!(
        "paper: TPU energy ~3x lower than CPU; measured ratio {:.1}x; std ≈ max.",
        off.energy_j / max.energy_j
    );

    println!("\n== Fig. 2d — cloud acceleration (cloud-only) ==");
    let mut t = Table::new(["cloud", "latency", "energy"]);
    for p in &r.fig2d_gpu {
        t.row([p.label.clone(), format!("{:.0} ms", p.latency_ms), format!("{:.2} J", p.energy_j)]);
    }
    t.print();

    println!("\n== Fig. 2e — accuracy vs split layer (TPU int8 head vs CPU fp32) ==");
    let mut t = Table::new(["split", "acc (TPU head)", "acc (CPU)"]);
    for k in 0..=22usize {
        let tpu = &r.fig2e_accuracy[2 * k];
        let cpu = &r.fig2e_accuracy[2 * k + 1];
        t.row([
            format!("{k}"),
            format!("{:.4}", tpu.accuracy),
            format!("{:.4}", cpu.accuracy),
        ]);
    }
    t.print();
    let max_delta = (0..=22)
        .map(|k| (r.fig2e_accuracy[2 * k].accuracy - r.fig2e_accuracy[2 * k + 1].accuracy).abs())
        .fold(0.0f64, f64::max);
    println!("paper: all deltas sub-percent; measured max delta {:.4}.", max_delta);
}

#[cfg(test)]
mod tests {
    use super::*;

    fn result() -> PrelimResult {
        run(&Ctx::synthetic(), 60, 1)
    }

    #[test]
    fn fig2a_latency_monotone_energy_decreasing() {
        let r = result();
        let lats: Vec<f64> = r.fig2a_cpu_freq.iter().map(|p| p.latency_ms).collect();
        assert!(lats.windows(2).all(|w| w[0] > w[1]), "{lats:?}");
        // energy decreasing apart from 0.8 GHz outlier wiggle
        let e: Vec<f64> = r.fig2a_cpu_freq.iter().map(|p| p.energy_j).collect();
        assert!(e.first().unwrap() > e.last().unwrap());
    }

    #[test]
    fn fig2c_tpu_cuts_energy_about_3x() {
        let r = result();
        let ratio = r.fig2c_tpu[0].energy_j / r.fig2c_tpu[2].energy_j;
        assert!((2.0..5.0).contains(&ratio), "ratio {ratio}");
        // std ≈ max (paper: no significant difference)
        let rel = (r.fig2c_tpu[1].latency_ms - r.fig2c_tpu[2].latency_ms).abs()
            / r.fig2c_tpu[2].latency_ms;
        assert!(rel < 0.2, "std vs max {rel}");
    }

    #[test]
    fn fig2d_gpu_faster_and_cheaper() {
        let r = result();
        assert!(r.fig2d_gpu[1].latency_ms < r.fig2d_gpu[0].latency_ms);
        assert!(r.fig2d_gpu[1].energy_j < r.fig2d_gpu[0].energy_j);
    }

    #[test]
    fn fig2e_subpercent_deltas() {
        let r = result();
        for k in 0..=22usize {
            let d = (r.fig2e_accuracy[2 * k].accuracy - r.fig2e_accuracy[2 * k + 1].accuracy).abs();
            assert!(d < 0.01, "split {k}: delta {d}");
        }
    }

    #[test]
    fn fig2b_split_nonmonotone() {
        let r = result();
        let lats: Vec<f64> = r.fig2b_split.iter().map(|p| p.latency_ms).collect();
        let rises = lats.windows(2).filter(|w| w[1] > w[0]).count();
        let falls = lats.windows(2).filter(|w| w[1] < w[0]).count();
        assert!(rises > 0 && falls > 0, "split sweep should be non-monotone");
    }

    #[test]
    fn report_prints() {
        print_report(&result());
    }
}
