//! Fig. 5 — the QoS (inference-time request) distributions for both
//! networks: Weibull(shape=1) rescaled to the Table-2 latency bounds.

use crate::space::Network;
use crate::util::rng::Pcg32;
use crate::util::stats::{density_sketch, sparkline, Summary};
use crate::util::table::Table;
use crate::workload::WorkloadGen;

#[derive(Debug, Clone)]
pub struct WorkloadDist {
    pub net: Network,
    pub qos_ms: Vec<f64>,
    pub summary: Summary,
}

pub fn run(net: Network, n: usize, seed: u64) -> WorkloadDist {
    let gen = WorkloadGen::paper(net);
    let mut rng = Pcg32::new(seed, 41);
    let qos_ms: Vec<f64> = gen.generate(n, &mut rng).iter().map(|r| r.qos_ms).collect();
    let summary = Summary::of(&qos_ms);
    WorkloadDist { net, qos_ms, summary }
}

pub fn print_report(dists: &[WorkloadDist]) {
    println!("\n== Fig. 5 — QoS request distributions (Weibull shape=1, Table-2 scaled) ==");
    let mut t = Table::new(["network", "n", "min", "median", "max", "density"]);
    for d in dists {
        t.row([
            d.net.name().to_string(),
            format!("{}", d.summary.count),
            format!("{:.1} ms", d.summary.min),
            format!("{:.1} ms", d.summary.median),
            format!("{:.1} ms", d.summary.max),
            sparkline(&density_sketch(&d.qos_ms, 30)),
        ]);
    }
    t.print();
    println!("paper shape: heavy right skew — most requests demand near-minimum latency.");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn distributions_span_table2_bounds() {
        let d = run(Network::Vgg16, 10_000, 1);
        assert!((d.summary.min - 90.6).abs() < 1e-6);
        assert!((d.summary.max - 5026.8).abs() < 1e-6);
        let v = run(Network::Vit, 10_000, 1);
        assert!((v.summary.min - 118.8).abs() < 1e-6);
        assert!((v.summary.max - 10_287.6).abs() < 1e-6);
    }

    #[test]
    fn right_skew() {
        let d = run(Network::Vgg16, 10_000, 2);
        assert!(d.summary.median < d.summary.mean, "exponential: median < mean");
    }

    #[test]
    fn report_prints() {
        print_report(&[run(Network::Vgg16, 500, 3), run(Network::Vit, 500, 3)]);
    }
}
