//! Mixed-network serving experiment (beyond-paper; ROADMAP
//! "mixed-network serving", DESIGN.md §12).
//!
//! One pipeline serves interleaved vgg16 + vit traffic against
//! per-network Pareto stores.  Two questions:
//!
//! 1. **Sweep** — mix ratio × workers × policy: per-request results
//!    stay worker-count invariant (order-independent executors), and
//!    the per-network breakdowns reconcile with the aggregate report
//!    under every mix.
//! 2. **Mix shift** — the traffic composition flips mid-run
//!    (vgg16-heavy → vit-heavy), the scenario shape PR 4's world-shift
//!    machinery introduced: the same pipeline absorbs the flip with no
//!    reconfiguration storm beyond the per-network caches' cold
//!    activations, and [`post_shift_hit_rate`] reports QoS on the
//!    post-flip tail per network.

use crate::adapt::{ConfigStore, StoreMap};
use crate::controller::policy::ConfigSet;
use crate::controller::{PaperPolicy, PerRequestSimExecutor, SchedulingPolicy, StrictDeadlinePolicy};
use crate::serve::{run_pipeline_stores, PipelineConfig, ServeReport};
use crate::solver::{Solver, Strategy};
use crate::space::Network;
use crate::util::rng::Pcg32;
use crate::util::table::Table;
use crate::workload::{mixed_timeline, ArrivalProcess, NetworkMix, TimedRequest, WorkloadGen};

use super::adaptation::post_shift_hit_rate;
use super::Ctx;

/// One pipeline run under a (mix, workers, policy) combination.
#[derive(Debug, Clone)]
pub struct Row {
    pub mix_label: &'static str,
    pub policy: &'static str,
    pub workers: usize,
    pub report: ServeReport,
}

/// The mid-run mix-flip scenario.
#[derive(Debug, Clone)]
pub struct MixShift {
    /// Request id at which the composition flips.
    pub shift_at: usize,
    pub report: ServeReport,
}

pub struct MixedExperiment {
    pub requests: usize,
    pub rows: Vec<Row>,
    pub shift: MixShift,
}

/// Executor stream selector shared by every run: outcomes depend only
/// on the request, so rows are comparable across mixes and workers.
const EXEC_STREAM: u64 = 9001;

/// Shared open-loop arrival rate; the mix-shift scenario derives its
/// inter-phase pad (one mean interarrival) from the same constant.
const RATE_PER_S: f64 = 150.0;

fn gen_for(net: Network) -> WorkloadGen {
    let mut g = WorkloadGen::paper(net);
    g.inferences_per_request = 200;
    g
}

pub fn run(ctx: &Ctx, requests: usize, seed: u64) -> MixedExperiment {
    // offline phase, once per network: each network gets its own front
    let mut fronts = Vec::new();
    for net in Network::ALL {
        let mut solver = Solver::new(&ctx.testbed, net);
        solver.batch_per_trial = 60;
        let pareto = solver.run(Strategy::NsgaIII, 120, seed).pareto;
        fronts.push((net, ConfigStore::new(ConfigSet::new(pareto))));
    }
    let mut stores = StoreMap::new();
    for (net, store) in &fronts {
        stores.insert(*net, store);
    }

    let mixes: [(&'static str, NetworkMix); 3] = [
        ("vgg16 only", NetworkMix::single(Network::Vgg16)),
        ("70/30", NetworkMix::parse("vgg16=0.7,vit=0.3").expect("static mix")),
        ("30/70", NetworkMix::parse("vgg16=0.3,vit=0.7").expect("static mix")),
    ];
    let process = ArrivalProcess::Poisson { rate_per_s: RATE_PER_S };

    let paper = PaperPolicy;
    let strict = StrictDeadlinePolicy;
    let mut rows = Vec::new();
    let mut launch = |mix_label: &'static str,
                      tl: &[TimedRequest],
                      policy_name: &'static str,
                      policy: &dyn SchedulingPolicy,
                      workers: usize| {
        let cfg = PipelineConfig {
            workers,
            queue_capacity: requests.max(64),
            max_batch: 4,
            time_scale: 0.0,
            seed,
            reuse: true,
            ..PipelineConfig::default()
        };
        let report = run_pipeline_stores(&stores, policy, tl, &cfg, None, None, |_| {
            Ok(PerRequestSimExecutor { testbed: &ctx.testbed, stream: EXEC_STREAM })
        })
        .expect("mixed pipeline run");
        rows.push(Row { mix_label, policy: policy_name, workers, report });
    };
    for &(label, ref mix) in &mixes {
        // one shared timeline per mix so rows differ only in pipeline shape
        let mut rng = Pcg32::new(seed, 231);
        let tl = mixed_timeline(mix, gen_for, &process, requests, &mut rng);
        for workers in [1, 2, 4] {
            launch(label, &tl, "paper", &paper, workers);
        }
        launch(label, &tl, "strict", &strict, 2);
    }

    // mix shift: vgg16-heavy first half, vit-heavy second half, one run
    let shift_at = requests / 2;
    let pre = NetworkMix::parse("vgg16=0.8,vit=0.2").expect("static mix");
    let post = NetworkMix::parse("vgg16=0.2,vit=0.8").expect("static mix");
    let mut rng = Pcg32::new(seed, 232);
    let mut tl = mixed_timeline(&pre, gen_for, &process, shift_at, &mut rng);
    let offset = tl.last().map_or(0.0, |tr| tr.arrival_ms) + 1000.0 / RATE_PER_S;
    let tail = mixed_timeline(&post, gen_for, &process, requests - shift_at, &mut rng);
    tl.extend(tail.into_iter().map(|mut tr| {
        tr.request.id += shift_at;
        tr.arrival_ms += offset;
        tr
    }));
    let cfg = PipelineConfig {
        workers: 2,
        queue_capacity: requests.max(64),
        max_batch: 4,
        time_scale: 0.0,
        seed,
        reuse: true,
        ..PipelineConfig::default()
    };
    let report = run_pipeline_stores(&stores, &paper, &tl, &cfg, None, None, |_| {
        Ok(PerRequestSimExecutor { testbed: &ctx.testbed, stream: EXEC_STREAM })
    })
    .expect("mix-shift run");

    MixedExperiment { requests, rows, shift: MixShift { shift_at, report } }
}

pub fn print_report(exp: &MixedExperiment) {
    println!(
        "\n== mixed-network serving — vgg16 + vit in one pipeline ({} requests/run) ==",
        exp.requests
    );
    let mut t = Table::new([
        "mix", "policy", "workers", "done", "QoS hit", "J/req", "vgg16 done", "vgg16 QoS",
        "vit done", "vit QoS",
    ]);
    for row in &exp.rows {
        let r = &row.report;
        let vgg = r.breakdown_for(Network::Vgg16);
        let vit = r.breakdown_for(Network::Vit);
        t.row([
            row.mix_label.to_string(),
            row.policy.to_string(),
            row.workers.to_string(),
            r.completed().to_string(),
            format!("{:.0}%", r.qos_hit_rate() * 100.0),
            format!("{:.2}", r.mean_energy_j()),
            format!("{}/{}", vgg.done, vgg.requests),
            format!("{:.0}%", vgg.qos_hit_rate() * 100.0),
            format!("{}/{}", vit.done, vit.requests),
            format!("{:.0}%", vit.qos_hit_rate() * 100.0),
        ]);
    }
    t.print();
    let s = &exp.shift;
    println!(
        "mix shift at request {}: composition flips vgg16-heavy -> vit-heavy mid-run; \
         post-shift QoS {:.0}% overall ({} reconfigs, {} avoided across both networks)",
        s.shift_at,
        post_shift_hit_rate(&s.report, s.shift_at) * 100.0,
        s.report.cache.reconfigs,
        s.report.cache.hits,
    );
    println!("per-run summary (shift scenario): {}", s.report.summary_line());
}

#[cfg(test)]
mod tests {
    use super::*;

    fn experiment() -> MixedExperiment {
        run(&Ctx::synthetic(), 72, 29)
    }

    #[test]
    fn sweep_covers_mixes_workers_and_policies() {
        let exp = experiment();
        assert_eq!(exp.rows.len(), 12, "3 mixes x (3 paper worker counts + 1 strict)");
        for row in &exp.rows {
            assert_eq!(
                row.report.records.len(),
                72,
                "{} {} w{}: every request accounted",
                row.mix_label,
                row.policy,
                row.workers
            );
            assert_eq!(row.report.unknown_network(), 0, "both networks bound");
        }
        // mixed rows really serve both networks
        let mixed_row = exp
            .rows
            .iter()
            .find(|r| r.mix_label == "70/30" && r.policy == "paper")
            .expect("70/30 paper row");
        assert_eq!(mixed_row.report.networks().len(), 2);
    }

    #[test]
    fn paper_rows_are_worker_count_invariant_per_mix() {
        let exp = experiment();
        for label in ["vgg16 only", "70/30", "30/70"] {
            let rows: Vec<&Row> = exp
                .rows
                .iter()
                .filter(|r| r.mix_label == label && r.policy == "paper")
                .collect();
            assert_eq!(rows.len(), 3);
            let (e0, q0) = (rows[0].report.mean_energy_j(), rows[0].report.qos_hit_rate());
            for row in &rows[1..] {
                assert_eq!(row.report.mean_energy_j(), e0, "{label}");
                assert_eq!(row.report.qos_hit_rate(), q0, "{label}");
            }
        }
    }

    #[test]
    fn per_network_accounting_reconciles_on_mixed_rows() {
        let exp = experiment();
        for row in &exp.rows {
            let parts = row.report.breakdown();
            assert_eq!(
                parts.iter().map(|b| b.requests).sum::<usize>(),
                row.report.records.len()
            );
            assert_eq!(parts.iter().map(|b| b.done).sum::<usize>(), row.report.completed());
            let energy: f64 = parts.iter().map(|b| b.energy_sum_j).sum();
            let want = row.report.mean_energy_j() * row.report.completed() as f64;
            if row.report.completed() > 0 {
                assert!((energy - want).abs() < 1e-6, "{} {}", row.mix_label, row.policy);
            }
        }
    }

    #[test]
    fn mix_shift_serves_both_phases_through_one_pipeline() {
        let exp = experiment();
        let s = &exp.shift;
        assert_eq!(s.report.records.len(), 72, "no request lost across the flip");
        // the flip is visible in the composition: vit dominates the tail
        let tail: Vec<_> =
            s.report.records.iter().filter(|r| r.request_id >= s.shift_at).collect();
        let tail_vit = tail.iter().filter(|r| r.net == Network::Vit).count();
        assert!(
            tail_vit * 2 > tail.len(),
            "post-shift tail should be vit-heavy: {tail_vit}/{}",
            tail.len()
        );
        let head_vit = s
            .report
            .records
            .iter()
            .filter(|r| r.request_id < s.shift_at && r.net == Network::Vit)
            .count();
        assert!(head_vit * 2 < s.shift_at, "pre-shift head should be vgg16-heavy");
        assert!(post_shift_hit_rate(&s.report, s.shift_at) > 0.0);
    }

    #[test]
    fn report_prints() {
        print_report(&experiment());
    }
}
