//! The Testbed Experiment (§6.3, Fig. 6–9): 50 requests per network,
//! DynaSplit vs the four static baselines (§6.2.3), on the simulated
//! testbed with fresh trials per request.

use crate::controller::{Controller, SimExecutor, StaticBaseline};
use crate::metrics::MetricSet;
use crate::simulator::Testbed;
use crate::solver::{ParetoEntry, Solver, Strategy};
use crate::space::{Config, Network, TpuMode};
use crate::util::rng::Pcg32;
use crate::util::table::Table;
use crate::workload::{Request, WorkloadGen};

use super::Ctx;

/// The five strategies' metric sets (§6.2.3 baselines + DynaSplit).
#[derive(Debug, Clone)]
pub struct StrategySet {
    pub cloud: MetricSet,
    pub edge: MetricSet,
    pub latency: MetricSet,
    pub energy: MetricSet,
    pub dynasplit: MetricSet,
}

impl StrategySet {
    pub fn all(&self) -> [&MetricSet; 5] {
        [&self.cloud, &self.edge, &self.latency, &self.energy, &self.dynasplit]
    }
}

/// Complete testbed-experiment output for one network.
#[derive(Debug, Clone)]
pub struct TestbedExp {
    pub net: Network,
    pub pareto: Vec<ParetoEntry>,
    pub strategies: StrategySet,
}

/// §6.2.3 (i): cloud-only baseline — GPU on, edge CPU at max.
pub fn cloud_baseline(net: Network) -> Config {
    crate::space::feasible::repair(Config {
        net,
        cpu_idx: crate::space::CPU_FREQS_GHZ.len() - 1,
        tpu: TpuMode::Off,
        gpu: true,
        split: 0,
    })
}

/// §6.2.3 (ii): edge-only baseline — TPU at max where usable (VGG16),
/// off otherwise (ViT), CPU at max.
pub fn edge_baseline(net: Network) -> Config {
    crate::space::feasible::repair(Config {
        net,
        cpu_idx: crate::space::CPU_FREQS_GHZ.len() - 1,
        tpu: if net.tpu_capable() { TpuMode::Max } else { TpuMode::Off },
        gpu: false,
        split: net.num_layers(),
    })
}

fn static_entry(config: Config) -> ParetoEntry {
    // metric fields are irrelevant for a static baseline (it never selects)
    ParetoEntry { config, latency_ms: f64::NAN, energy_j: f64::NAN, accuracy: f64::NAN }
}

/// §6.2.3 (iii): fastest configuration from the non-dominated set.
pub fn fastest_entry(pareto: &[ParetoEntry]) -> ParetoEntry {
    pareto
        .iter()
        .min_by(|a, b| a.latency_ms.total_cmp(&b.latency_ms))
        .expect("empty pareto set")
        .clone()
}

/// §6.2.3 (iv): most energy-efficient configuration from the set.
pub fn energy_entry(pareto: &[ParetoEntry]) -> ParetoEntry {
    pareto
        .iter()
        .min_by(|a, b| a.energy_j.total_cmp(&b.energy_j))
        .expect("empty pareto set")
        .clone()
}

/// Serve one workload under all five strategies with fresh trials.
pub fn serve_strategies(
    testbed: &Testbed,
    pareto: Vec<ParetoEntry>,
    requests: &[Request],
    seed: u64,
) -> StrategySet {
    let net = requests[0].net;
    let exec = |s: u64| SimExecutor::Fresh { testbed, rng: Pcg32::new(seed, 200 + s) };
    let cloud = StaticBaseline { entry: static_entry(cloud_baseline(net)) }
        .serve(requests, &mut exec(0), "cloud");
    let edge = StaticBaseline { entry: static_entry(edge_baseline(net)) }
        .serve(requests, &mut exec(1), "edge");
    let latency = StaticBaseline { entry: fastest_entry(&pareto) }
        .serve(requests, &mut exec(2), "latency");
    let energy = StaticBaseline { entry: energy_entry(&pareto) }
        .serve(requests, &mut exec(3), "energy");
    let mut controller = Controller::new(pareto, seed);
    let dynasplit = controller.serve(requests, &mut exec(4), "dynasplit");
    StrategySet { cloud, edge, latency, energy, dynasplit }
}

/// Run the full testbed experiment for `net`.
pub fn run(
    ctx: &Ctx,
    net: Network,
    n_requests: usize,
    trial_batch: usize,
    seed: u64,
) -> TestbedExp {
    // Offline phase: NSGA-III over 20% of the space (§6.3.4).
    let mut solver = Solver::new(&ctx.testbed, net);
    solver.batch_per_trial = trial_batch;
    let trials = solver.trials_for_fraction(0.2);
    let out = solver.run(Strategy::NsgaIII, trials, seed);

    // Online phase: 50-request workload (§6.2.1).
    let gen = WorkloadGen::paper(net);
    let mut rng = Pcg32::new(seed, 51);
    let requests = gen.generate(n_requests, &mut rng);
    let strategies = serve_strategies(&ctx.testbed, out.pareto.clone(), &requests, seed);
    TestbedExp { net, pareto: out.pareto, strategies }
}

pub fn print_report(exp: &TestbedExp) {
    let s = &exp.strategies;
    println!(
        "\n===== Testbed Experiment — {} ({} requests, |pareto| = {}) =====",
        exp.net.name(),
        s.dynasplit.len(),
        exp.pareto.len()
    );

    // --- Fig. 6: scheduling decisions ---
    let (cloud, split, edge) = s.dynasplit.placement_counts();
    println!("\n== Fig. 6 — DynaSplit scheduling decisions ==");
    let paper = match exp.net {
        Network::Vgg16 => "paper: 2 cloud / 11 split / 37 edge",
        Network::Vit => "paper: 1 cloud / 49 split / 0 edge",
    };
    println!("measured: {cloud} cloud / {split} split / {edge} edge   ({paper})");

    // --- Fig. 7: latency distributions ---
    println!("\n== Fig. 7 — latency distributions ==");
    let mut t = Table::new(["strategy", "median", "q1", "q3", "violin"]);
    for m in s.all() {
        let sum = m.latency_summary();
        t.row([
            m.strategy.clone(),
            format!("{:.0} ms", sum.median),
            format!("{:.0} ms", sum.q1),
            format!("{:.0} ms", sum.q3),
            m.latency_violin(),
        ]);
    }
    t.print();

    // --- Fig. 8: QoS violations ---
    println!("\n== Fig. 8 — QoS violations ==");
    let mut t = Table::new(["strategy", "violations", "rate", "median exceedance"]);
    for m in s.all() {
        let med = m
            .violation_summary()
            .map(|v| format!("{:.0} ms", v.median))
            .unwrap_or_else(|| "-".to_string());
        t.row([
            m.strategy.clone(),
            format!("{}", m.violations()),
            format!("{:.0}%", 100.0 * (1.0 - m.qos_met_fraction())),
            med,
        ]);
    }
    t.print();

    // --- Fig. 9: energy ---
    println!("\n== Fig. 9 — energy distributions ==");
    let mut t = Table::new(["strategy", "median", "q1", "q3", "max"]);
    for m in s.all() {
        let sum = m.energy_summary();
        t.row([
            m.strategy.clone(),
            format!("{:.1} J", sum.median),
            format!("{:.1} J", sum.q1),
            format!("{:.1} J", sum.q3),
            format!("{:.1} J", sum.max),
        ]);
    }
    t.print();

    // --- headline ---
    let reduction =
        1.0 - s.dynasplit.energy_summary().median / s.cloud.energy_summary().median;
    println!(
        "\nheadline: median energy vs cloud-only: -{:.0}%  (paper: up to 72%); \
         QoS met: {:.0}% (paper: ~90%)",
        reduction * 100.0,
        s.dynasplit.qos_met_fraction() * 100.0
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    fn exp(net: Network) -> TestbedExp {
        run(&Ctx::synthetic(), net, 50, 60, 3)
    }

    #[test]
    fn vgg_matches_paper_shape() {
        let e = exp(Network::Vgg16);
        let s = &e.strategies;
        // Fig. 7 ordering: cloud/latency fast, edge/energy slow.
        assert!(s.cloud.latency_summary().median < 150.0);
        assert!(s.edge.latency_summary().median > 300.0);
        // Fig. 9 ordering: cloud expensive, edge cheap.
        assert!(s.cloud.energy_summary().median > 20.0 * s.edge.energy_summary().median);
        // headline: DynaSplit ~90% QoS met, big energy cut vs cloud.
        assert!(s.dynasplit.qos_met_fraction() > 0.8, "{}", s.dynasplit.qos_met_fraction());
        let cut = 1.0 - s.dynasplit.energy_summary().median / s.cloud.energy_summary().median;
        assert!(cut > 0.5, "energy cut only {cut}");
        // Fig. 6: VGG leans edge-heavy (paper: 37/50 edge).
        let (_c, _s, edge) = s.dynasplit.placement_counts();
        assert!(edge > 15, "edge share too low: {edge}");
    }

    #[test]
    fn vit_mostly_splits() {
        let e = exp(Network::Vit);
        // Paper Fig. 6: ViT = 1 cloud / 49 split / 0 edge.  The zero is a
        // *search-path artifact*: the paper's 56-trial ViT search simply
        // never retained an edge-only config ("the Solver did not identify
        // any edge-only configuration"), even though its own Fig. 9 shows
        // edge-only ViT (16 J) is cheaper than the front's energy
        // baseline (80 J) — i.e. edge-only was non-dominated but unseen.
        // Our search covers the space more thoroughly and legitimately
        // keeps those configs, so lenient-QoS requests may go edge; we
        // assert the dominant behaviour (split) matches the paper and
        // document the divergence in EXPERIMENTS.md.
        let (_cloud, split, edge) = e.strategies.dynasplit.placement_counts();
        assert!(split >= 20, "ViT should mostly split: {split}");
        assert!(edge <= 20, "ViT edge decisions unexpectedly dominant: {edge}");
    }

    #[test]
    fn baseline_configs_match_section_623() {
        let c = cloud_baseline(Network::Vgg16);
        assert!(c.is_cloud_only() && c.gpu && c.cpu_idx == 6 && c.tpu == TpuMode::Off);
        let e = edge_baseline(Network::Vgg16);
        assert!(e.is_edge_only() && !e.gpu && e.tpu == TpuMode::Max);
        let ev = edge_baseline(Network::Vit);
        assert!(ev.tpu == TpuMode::Off, "ViT edge baseline must not use TPU");
    }

    #[test]
    fn report_prints() {
        print_report(&exp(Network::Vgg16));
    }
}
