//! Descriptive statistics + numerical integration.
//!
//! The paper reports distributions as violin plots with quartile lines;
//! [`Summary`] captures the same information textually (quartiles, median,
//! whiskers, a coarse density sketch).  [`trapezoid`] is the exact energy
//! integration the paper performs over sampled power-meter readings.

/// Five-number summary + mean/count over a sample.
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    pub count: usize,
    pub min: f64,
    pub q1: f64,
    pub median: f64,
    pub q3: f64,
    pub max: f64,
    pub mean: f64,
}

impl Summary {
    /// Compute from unsorted data. Panics on empty input.
    ///
    /// NaN samples indicate an upstream bug: flagged loudly in debug
    /// builds, while release builds stay panic-free (`total_cmp` sorts
    /// NaN deterministically to the top, so it surfaces in `max`).
    pub fn of(data: &[f64]) -> Summary {
        assert!(!data.is_empty(), "Summary::of(empty)");
        debug_assert!(!data.iter().any(|x| x.is_nan()), "NaN sample in Summary::of");
        let mut v: Vec<f64> = data.to_vec();
        v.sort_by(f64::total_cmp);
        let mean = v.iter().sum::<f64>() / v.len() as f64;
        Summary {
            count: v.len(),
            min: v[0],
            q1: quantile_sorted(&v, 0.25),
            median: quantile_sorted(&v, 0.5),
            q3: quantile_sorted(&v, 0.75),
            max: *v.last().unwrap(),
            mean,
        }
    }

    /// Inter-quartile range.
    pub fn iqr(&self) -> f64 {
        self.q3 - self.q1
    }

    /// One-line rendering used throughout the experiment reports.
    pub fn line(&self, unit: &str) -> String {
        format!(
            "n={:<6} min={:>9.1}{u} q1={:>9.1}{u} med={:>9.1}{u} q3={:>9.1}{u} max={:>9.1}{u} mean={:>9.1}{u}",
            self.count, self.min, self.q1, self.median, self.q3, self.max, self.mean,
            u = unit
        )
    }
}

/// Linear-interpolated quantile of *sorted* data, q in [0, 1].
pub fn quantile_sorted(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    assert!((0.0..=1.0).contains(&q));
    if sorted.len() == 1 {
        return sorted[0];
    }
    let pos = q * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    let frac = pos - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Quantile of unsorted data.
pub fn quantile(data: &[f64], q: f64) -> f64 {
    debug_assert!(!data.iter().any(|x| x.is_nan()), "NaN sample in quantile");
    let mut v = data.to_vec();
    v.sort_by(f64::total_cmp);
    quantile_sorted(&v, q)
}

/// Median convenience.
pub fn median(data: &[f64]) -> f64 {
    quantile(data, 0.5)
}

pub fn mean(data: &[f64]) -> f64 {
    assert!(!data.is_empty());
    data.iter().sum::<f64>() / data.len() as f64
}

pub fn stddev(data: &[f64]) -> f64 {
    let m = mean(data);
    (data.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / data.len() as f64).sqrt()
}

/// Trapezoidal integration of irregularly sampled `(t, y)` points — the
/// paper's energy computation over power-meter samples (§6.1): E = ∫P dt.
pub fn trapezoid(samples: &[(f64, f64)]) -> f64 {
    samples
        .windows(2)
        .map(|w| 0.5 * (w[1].1 + w[0].1) * (w[1].0 - w[0].0))
        .sum()
}

/// Coarse density sketch: histogram of `bins` counts over [min, max] —
/// the textual stand-in for a violin shape in our reports.
pub fn density_sketch(data: &[f64], bins: usize) -> Vec<usize> {
    assert!(bins > 0);
    if data.is_empty() {
        return vec![0; bins];
    }
    let lo = data.iter().cloned().fold(f64::INFINITY, f64::min);
    let hi = data.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    let mut counts = vec![0usize; bins];
    let width = (hi - lo).max(1e-12);
    for &x in data {
        let b = (((x - lo) / width) * bins as f64) as usize;
        counts[b.min(bins - 1)] += 1;
    }
    counts
}

/// Render a density sketch as a unicode sparkline (report aesthetics).
pub fn sparkline(counts: &[usize]) -> String {
    const BARS: [char; 8] = ['▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];
    let max = counts.iter().copied().max().unwrap_or(0).max(1);
    counts
        .iter()
        .map(|&c| BARS[(c * (BARS.len() - 1) + max / 2) / max])
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_of_known_data() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.median, 3.0);
        assert_eq!(s.max, 5.0);
        assert_eq!(s.mean, 3.0);
        assert_eq!(s.q1, 2.0);
        assert_eq!(s.q3, 4.0);
    }

    #[test]
    fn summary_single_element() {
        let s = Summary::of(&[7.0]);
        assert_eq!(s.min, 7.0);
        assert_eq!(s.q1, 7.0);
        assert_eq!(s.max, 7.0);
    }

    #[test]
    #[should_panic(expected = "empty")]
    fn summary_empty_panics() {
        Summary::of(&[]);
    }

    #[test]
    fn quantile_interpolates() {
        let v = [0.0, 10.0];
        assert_eq!(quantile_sorted(&v, 0.5), 5.0);
        assert_eq!(quantile_sorted(&v, 0.25), 2.5);
    }

    #[test]
    fn trapezoid_constant_power() {
        // 5 W for 2 s = 10 J, regardless of sampling grid.
        let s = [(0.0, 5.0), (0.7, 5.0), (1.1, 5.0), (2.0, 5.0)];
        assert!((trapezoid(&s) - 10.0).abs() < 1e-12);
    }

    #[test]
    fn trapezoid_linear_ramp() {
        // P(t) = t over [0, 2] -> 2 J.
        let s: Vec<(f64, f64)> = (0..=20).map(|i| (i as f64 * 0.1, i as f64 * 0.1)).collect();
        assert!((trapezoid(&s) - 2.0).abs() < 1e-9);
    }

    #[test]
    fn trapezoid_empty_and_single() {
        assert_eq!(trapezoid(&[]), 0.0);
        assert_eq!(trapezoid(&[(0.0, 3.0)]), 0.0);
    }

    #[test]
    fn density_sketch_sums_to_n() {
        let data: Vec<f64> = (0..100).map(|i| i as f64).collect();
        let sketch = density_sketch(&data, 10);
        assert_eq!(sketch.iter().sum::<usize>(), 100);
        assert!(sketch.iter().all(|&c| c == 10));
    }

    #[test]
    fn sparkline_length() {
        assert_eq!(sparkline(&[0, 1, 2, 3]).chars().count(), 4);
    }
}
