//! Poison-recovering lock helpers (dslint `no-panic-hot-path`,
//! DESIGN.md §13).
//!
//! The serving stack's contract is *shedding, not crashing*: a worker
//! that panicked while holding a lock must not cascade into every other
//! worker panicking on `PoisonError`.  All of the data these locks
//! protect (queue deques, telemetry rings, store snapshots, batch logs)
//! is written transactionally — each critical section either completes
//! its whole update or was a read — so the state behind a poisoned lock
//! is still coherent and the right recovery is to keep serving with it.
//! These helpers strip the poison flag and hand back the guard; the
//! panic that poisoned the lock still surfaces through the pipeline's
//! `join` handling, so failures are reported, not masked.

use std::sync::{Condvar, Mutex, MutexGuard, RwLock, RwLockReadGuard, RwLockWriteGuard};
use std::time::Duration;

/// Lock a mutex, recovering the guard from a poisoned lock.
pub fn lock_clean<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// Read-lock an `RwLock`, recovering the guard from a poisoned lock.
pub fn read_clean<T>(l: &RwLock<T>) -> RwLockReadGuard<'_, T> {
    l.read().unwrap_or_else(|e| e.into_inner())
}

/// Write-lock an `RwLock`, recovering the guard from a poisoned lock.
pub fn write_clean<T>(l: &RwLock<T>) -> RwLockWriteGuard<'_, T> {
    l.write().unwrap_or_else(|e| e.into_inner())
}

/// Block on a condvar, recovering the guard from a poisoned lock.
pub fn wait_clean<'a, T>(cv: &Condvar, guard: MutexGuard<'a, T>) -> MutexGuard<'a, T> {
    cv.wait(guard).unwrap_or_else(|e| e.into_inner())
}

/// Block on a condvar with a timeout; returns the guard and whether the
/// wait timed out.
pub fn wait_timeout_clean<'a, T>(
    cv: &Condvar,
    guard: MutexGuard<'a, T>,
    timeout: Duration,
) -> (MutexGuard<'a, T>, bool) {
    match cv.wait_timeout(guard, timeout) {
        Ok((g, t)) => (g, t.timed_out()),
        Err(e) => {
            let (g, t) = e.into_inner();
            (g, t.timed_out())
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::{Arc, Condvar, Mutex, RwLock};

    #[test]
    fn helpers_behave_like_plain_locking_when_unpoisoned() {
        let m = Mutex::new(1);
        *lock_clean(&m) += 1;
        assert_eq!(*lock_clean(&m), 2);
        let l = RwLock::new(3);
        assert_eq!(*read_clean(&l), 3);
        *write_clean(&l) += 1;
        assert_eq!(*read_clean(&l), 4);
    }

    #[test]
    fn poisoned_mutex_recovers_with_coherent_state() {
        let m = Arc::new(Mutex::new(7));
        let m2 = m.clone();
        let _ = std::thread::spawn(move || {
            let _g = m2.lock().unwrap();
            panic!("poison it");
        })
        .join();
        assert!(m.is_poisoned());
        // the panicking section made no partial write: state is intact
        assert_eq!(*lock_clean(&m), 7);
        *lock_clean(&m) += 1;
        assert_eq!(*lock_clean(&m), 8);
    }

    #[test]
    fn poisoned_rwlock_recovers_for_readers_and_writers() {
        let l = Arc::new(RwLock::new(5));
        let l2 = l.clone();
        let _ = std::thread::spawn(move || {
            let _g = l2.write().unwrap();
            panic!("poison it");
        })
        .join();
        assert!(l.is_poisoned());
        assert_eq!(*read_clean(&l), 5);
        *write_clean(&l) = 6;
        assert_eq!(*read_clean(&l), 6);
    }

    #[test]
    fn wait_timeout_clean_reports_timeouts() {
        let m = Mutex::new(());
        let cv = Condvar::new();
        let g = lock_clean(&m);
        let (_g, timed_out) = wait_timeout_clean(&cv, g, Duration::from_millis(5));
        assert!(timed_out, "nothing ever notifies: the wait must time out");
    }

    #[test]
    fn wait_clean_wakes_on_notify() {
        let pair = Arc::new((Mutex::new(false), Condvar::new()));
        let pair2 = pair.clone();
        let waiter = std::thread::spawn(move || {
            let (m, cv) = &*pair2;
            let mut ready = lock_clean(m);
            while !*ready {
                ready = wait_clean(cv, ready);
            }
        });
        {
            let (m, cv) = &*pair;
            *lock_clean(m) = true;
            cv.notify_all();
        }
        waiter.join().unwrap();
    }
}
