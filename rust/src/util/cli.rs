//! Tiny declarative CLI argument parser (clap substitute).
//!
//! Supports `--flag`, `--key value`, `--key=value`, positional arguments,
//! and generated `--help`.  Used by `main.rs` subcommands, the examples,
//! and the bench harness.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

/// One declared option.
#[derive(Debug, Clone)]
struct Opt {
    name: &'static str,
    help: &'static str,
    takes_value: bool,
    default: Option<String>,
}

/// Declarative parser: declare options, then [`Args::parse`].
#[derive(Debug, Clone)]
pub struct ArgSpec {
    program: String,
    about: &'static str,
    opts: Vec<Opt>,
}

impl ArgSpec {
    pub fn new(program: impl Into<String>, about: &'static str) -> Self {
        ArgSpec { program: program.into(), about, opts: Vec::new() }
    }

    /// `--name <value>` with a default.
    pub fn opt(mut self, name: &'static str, default: &str, help: &'static str) -> Self {
        self.opts.push(Opt { name, help, takes_value: true, default: Some(default.into()) });
        self
    }

    /// `--name <value>`, optional, no default.
    pub fn opt_maybe(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(Opt { name, help, takes_value: true, default: None });
        self
    }

    /// Boolean `--name` flag.
    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(Opt { name, help, takes_value: false, default: None });
        self
    }

    pub fn usage(&self) -> String {
        let mut s = format!("{} — {}\n\noptions:\n", self.program, self.about);
        for o in &self.opts {
            let head = if o.takes_value {
                format!("  --{} <value>", o.name)
            } else {
                format!("  --{}", o.name)
            };
            let default = o
                .default
                .as_ref()
                .map(|d| format!(" [default: {d}]"))
                .unwrap_or_default();
            s.push_str(&format!("{head:28} {}{default}\n", o.help));
        }
        s.push_str("  --help                       print this message\n");
        s
    }

    /// Parse a raw token stream (without the program name).
    pub fn parse<I>(&self, raw: I) -> Result<Args>
    where
        I: IntoIterator<Item = String>,
    {
        let mut values: BTreeMap<String, String> = BTreeMap::new();
        let mut flags: Vec<String> = Vec::new();
        let mut positional: Vec<String> = Vec::new();
        let mut iter = raw.into_iter().peekable();
        while let Some(tok) = iter.next() {
            if tok == "--help" || tok == "-h" {
                bail!("{}", self.usage());
            }
            if let Some(stripped) = tok.strip_prefix("--") {
                let (name, inline) = match stripped.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (stripped.to_string(), None),
                };
                let Some(opt) = self.opts.iter().find(|o| o.name == name) else {
                    bail!("unknown option --{name}\n\n{}", self.usage());
                };
                if opt.takes_value {
                    let v = match inline {
                        Some(v) => v,
                        None => iter
                            .next()
                            .ok_or_else(|| anyhow::anyhow!("--{name} requires a value"))?,
                    };
                    values.insert(name, v);
                } else {
                    if inline.is_some() {
                        bail!("--{name} does not take a value");
                    }
                    flags.push(name);
                }
            } else {
                positional.push(tok);
            }
        }
        // defaults
        for o in &self.opts {
            if let Some(d) = &o.default {
                values.entry(o.name.to_string()).or_insert_with(|| d.clone());
            }
        }
        Ok(Args { values, flags, positional })
    }

    /// Parse `std::env::args()` minus program name and subcommand tokens.
    pub fn parse_env(&self, skip: usize) -> Result<Args> {
        self.parse(std::env::args().skip(skip))
    }
}

/// Parsed arguments with typed getters.
#[derive(Debug, Clone, Default)]
pub struct Args {
    values: BTreeMap<String, String>,
    flags: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(|s| s.as_str())
    }

    pub fn str(&self, name: &str) -> Result<&str> {
        self.get(name)
            .ok_or_else(|| anyhow::anyhow!("missing required option --{name}"))
    }

    pub fn usize(&self, name: &str) -> Result<usize> {
        Ok(self.str(name)?.parse()?)
    }

    pub fn u64(&self, name: &str) -> Result<u64> {
        Ok(self.str(name)?.parse()?)
    }

    pub fn f64(&self, name: &str) -> Result<f64> {
        Ok(self.str(name)?.parse()?)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> ArgSpec {
        ArgSpec::new("t", "test")
            .opt("n", "10", "count")
            .opt_maybe("path", "a path")
            .flag("verbose", "log more")
    }

    fn parse(toks: &[&str]) -> Result<Args> {
        spec().parse(toks.iter().map(|s| s.to_string()))
    }

    #[test]
    fn defaults_apply() {
        let a = parse(&[]).unwrap();
        assert_eq!(a.usize("n").unwrap(), 10);
        assert!(a.get("path").is_none());
        assert!(!a.flag("verbose"));
    }

    #[test]
    fn space_and_equals_forms() {
        let a = parse(&["--n", "5", "--path=/tmp/x"]).unwrap();
        assert_eq!(a.usize("n").unwrap(), 5);
        assert_eq!(a.str("path").unwrap(), "/tmp/x");
    }

    #[test]
    fn flags_and_positional() {
        let a = parse(&["--verbose", "cmd1", "cmd2"]).unwrap();
        assert!(a.flag("verbose"));
        assert_eq!(a.positional, vec!["cmd1", "cmd2"]);
    }

    #[test]
    fn unknown_option_fails_with_usage() {
        let err = parse(&["--bogus"]).unwrap_err().to_string();
        assert!(err.contains("unknown option"));
        assert!(err.contains("--n"));
    }

    #[test]
    fn missing_value_fails() {
        assert!(parse(&["--n"]).is_err());
    }

    #[test]
    fn help_bails_with_usage() {
        let err = parse(&["--help"]).unwrap_err().to_string();
        assert!(err.contains("options:"));
    }
}
