//! Plain-text table rendering for experiment reports (the textual stand-in
//! for the paper's figures; every bench prints paper-vs-measured tables).

/// Column-aligned text table builder.
#[derive(Debug, Default, Clone)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new<S: Into<String>, I: IntoIterator<Item = S>>(header: I) -> Self {
        Table { header: header.into_iter().map(Into::into).collect(), rows: Vec::new() }
    }

    pub fn row<S: Into<String>, I: IntoIterator<Item = S>>(&mut self, cells: I) -> &mut Self {
        let row: Vec<String> = cells.into_iter().map(Into::into).collect();
        assert_eq!(
            row.len(),
            self.header.len(),
            "row width {} != header width {}",
            row.len(),
            self.header.len()
        );
        self.rows.push(row);
        self
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| display_width(h)).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate() {
                widths[i] = widths[i].max(display_width(cell));
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::new();
            for i in 0..ncol {
                if i > 0 {
                    line.push_str("  ");
                }
                let cell = &cells[i];
                line.push_str(cell);
                for _ in display_width(cell)..widths[i] {
                    line.push(' ');
                }
            }
            line.trim_end().to_string()
        };
        out.push_str(&fmt_row(&self.header, &widths));
        out.push('\n');
        let total: usize = widths.iter().sum::<usize>() + 2 * (ncol - 1);
        out.push_str(&"-".repeat(total));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }

    /// CSV emission for EXPERIMENTS.md data appendices.
    pub fn to_csv(&self) -> String {
        let esc = |s: &str| -> String {
            if s.contains(',') || s.contains('"') || s.contains('\n') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let mut out = String::new();
        out.push_str(&self.header.iter().map(|h| esc(h)).collect::<Vec<_>>().join(","));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }
}

/// Approximate displayed width (treats all chars as width 1; our tables
/// only use ASCII + sparkline blocks which are width-1 in monospace).
fn display_width(s: &str) -> usize {
    s.chars().count()
}

/// Format milliseconds human-readably.
pub fn fmt_ms(ms: f64) -> String {
    if ms >= 1000.0 {
        format!("{:.2} s", ms / 1000.0)
    } else if ms >= 1.0 {
        format!("{ms:.1} ms")
    } else {
        format!("{:.1} µs", ms * 1000.0)
    }
}

/// Format joules human-readably.
pub fn fmt_j(j: f64) -> String {
    if j >= 1.0 {
        format!("{j:.1} J")
    } else {
        format!("{:.1} mJ", j * 1000.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(["name", "value"]);
        t.row(["a", "1"]).row(["longer", "22"]);
        let r = t.render();
        let lines: Vec<&str> = r.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].starts_with("name"));
        assert!(lines[3].starts_with("longer"));
    }

    #[test]
    #[should_panic(expected = "row width")]
    fn mismatched_row_panics() {
        Table::new(["a", "b"]).row(["only-one"]);
    }

    #[test]
    fn csv_escapes() {
        let mut t = Table::new(["a", "b"]);
        t.row(["x,y", "q\"z"]);
        let csv = t.to_csv();
        assert!(csv.contains("\"x,y\""));
        assert!(csv.contains("\"q\"\"z\""));
    }

    #[test]
    fn fmt_units() {
        assert_eq!(fmt_ms(1500.0), "1.50 s");
        assert_eq!(fmt_ms(12.3), "12.3 ms");
        assert_eq!(fmt_ms(0.5), "500.0 µs");
        assert_eq!(fmt_j(2.5), "2.5 J");
        assert_eq!(fmt_j(0.2), "200.0 mJ");
    }
}
