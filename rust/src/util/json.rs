//! Minimal JSON codec (parser + writer), hand-rolled because `serde` is
//! unavailable offline.  Covers the full JSON grammar we exchange with the
//! Python build step (`artifacts/manifest.json`) and persist for the
//! solver (`pareto.json`, trial logs): objects, arrays, strings with
//! escapes, f64 numbers, bools, null.

use std::collections::BTreeMap;
use std::fmt;

use anyhow::{anyhow, bail, Context, Result};

/// A JSON value. Object keys are ordered (BTreeMap) so emission is
/// deterministic — experiment outputs diff cleanly between runs.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // ------------------------------------------------------------------
    // Typed accessors (with contextual errors for manifest diagnostics)
    // ------------------------------------------------------------------

    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Obj(m) => m.get(key).ok_or_else(|| anyhow!("missing key {key:?}")),
            _ => bail!("expected object while looking up {key:?}, got {self}"),
        }
    }

    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(x) => Ok(*x),
            _ => bail!("expected number, got {self}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let x = self.as_f64()?;
        if x < 0.0 || x.fract() != 0.0 {
            bail!("expected non-negative integer, got {x}");
        }
        Ok(x as usize)
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("expected string, got {self}"),
        }
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => bail!("expected bool, got {self}"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => bail!("expected array, got {self}"),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => bail!("expected object, got {self}"),
        }
    }

    /// Shorthand: array of f64.
    pub fn as_f64_vec(&self) -> Result<Vec<f64>> {
        self.as_arr()?.iter().map(|x| x.as_f64()).collect()
    }

    /// Shorthand: array of usize.
    pub fn as_usize_vec(&self) -> Result<Vec<usize>> {
        self.as_arr()?.iter().map(|x| x.as_usize()).collect()
    }

    // ------------------------------------------------------------------
    // Builders
    // ------------------------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }

    pub fn num(x: f64) -> Json {
        Json::Num(x)
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    // ------------------------------------------------------------------
    // Emission
    // ------------------------------------------------------------------

    /// Compact single-line encoding.
    pub fn encode(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => write_num(*x, out),
            Json::Str(s) => write_string(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_string(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    // ------------------------------------------------------------------
    // Parsing
    // ------------------------------------------------------------------

    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            bail!("trailing garbage at byte {}", p.pos);
        }
        Ok(v)
    }

    /// Parse a file with path context on error.
    pub fn parse_file(path: &std::path::Path) -> Result<Json> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Json::parse(&text).with_context(|| format!("parsing {}", path.display()))
    }
}

fn write_num(x: f64, out: &mut String) {
    if x.fract() == 0.0 && x.abs() < 9e15 {
        out.push_str(&format!("{}", x as i64));
    } else {
        // shortest round-trippable representation rust provides
        out.push_str(&format!("{x}"));
    }
}

fn write_string(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Result<u8> {
        let b = self.peek().ok_or_else(|| anyhow!("unexpected end of input"))?;
        self.pos += 1;
        Ok(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        let got = self.bump()?;
        if got != b {
            bail!("expected {:?} at byte {}, got {:?}", b as char, self.pos - 1, got as char);
        }
        Ok(())
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json> {
        for &b in lit.as_bytes() {
            self.expect(b)?;
        }
        Ok(v)
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek().ok_or_else(|| anyhow!("unexpected end of input"))? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.literal("true", Json::Bool(true)),
            b'f' => self.literal("false", Json::Bool(false)),
            b'n' => self.literal("null", Json::Null),
            b'-' | b'0'..=b'9' => self.number(),
            c => bail!("unexpected character {:?} at byte {}", c as char, self.pos),
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump()? {
                b',' => continue,
                b'}' => return Ok(Json::Obj(map)),
                c => bail!("expected ',' or '}}', got {:?}", c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump()? {
                b',' => continue,
                b']' => return Ok(Json::Arr(items)),
                c => bail!("expected ',' or ']', got {:?}", c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            let b = self.bump()?;
            match b {
                b'"' => return Ok(s),
                b'\\' => match self.bump()? {
                    b'"' => s.push('"'),
                    b'\\' => s.push('\\'),
                    b'/' => s.push('/'),
                    b'n' => s.push('\n'),
                    b't' => s.push('\t'),
                    b'r' => s.push('\r'),
                    b'b' => s.push('\u{8}'),
                    b'f' => s.push('\u{c}'),
                    b'u' => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let h = self.bump()?;
                            code = code * 16
                                + (h as char).to_digit(16).ok_or_else(|| {
                                    anyhow!("bad \\u escape at byte {}", self.pos - 1)
                                })?;
                        }
                        // surrogate pairs: only BMP needed for our data, but
                        // handle pairs for completeness.
                        if (0xD800..0xDC00).contains(&code) {
                            self.expect(b'\\')?;
                            self.expect(b'u')?;
                            let mut low = 0u32;
                            for _ in 0..4 {
                                let h = self.bump()?;
                                low = low * 16
                                    + (h as char).to_digit(16).ok_or_else(|| {
                                        anyhow!("bad \\u escape at byte {}", self.pos - 1)
                                    })?;
                            }
                            code = 0x10000 + ((code - 0xD800) << 10) + (low - 0xDC00);
                        }
                        s.push(char::from_u32(code).ok_or_else(|| anyhow!("bad codepoint"))?);
                    }
                    c => bail!("bad escape \\{}", c as char),
                },
                // multi-byte UTF-8: pass through raw (input is &str so valid)
                b if b >= 0x80 => {
                    let start = self.pos - 1;
                    let len = utf8_len(b);
                    for _ in 1..len {
                        self.bump()?;
                    }
                    s.push_str(std::str::from_utf8(&self.bytes[start..self.pos]).unwrap());
                }
                b => s.push(b as char),
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9')) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        let x: f64 = text.parse().map_err(|_| anyhow!("bad number {text:?}"))?;
        Ok(Json::Num(x))
    }
}

fn utf8_len(b: u8) -> usize {
    match b {
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.encode())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for text in ["null", "true", "false", "0", "-1", "3.5", "1e3", "\"hi\""] {
            let v = Json::parse(text).unwrap();
            let v2 = Json::parse(&v.encode()).unwrap();
            assert_eq!(v, v2, "{text}");
        }
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x\ny"}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x\ny");
    }

    #[test]
    fn parse_unicode_escape() {
        let v = Json::parse(r#""é😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "é😀");
    }

    #[test]
    fn parse_raw_utf8() {
        let v = Json::parse("\"héllo — ✓\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo — ✓");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("{'a':1}").is_err());
        assert!(Json::parse("").is_err());
    }

    #[test]
    fn integers_encode_without_fraction() {
        assert_eq!(Json::Num(5.0).encode(), "5");
        assert_eq!(Json::Num(5.5).encode(), "5.5");
        assert_eq!(Json::Num(-0.25).encode(), "-0.25");
    }

    #[test]
    fn string_escapes_roundtrip() {
        let s = "a\"b\\c\nd\te\r\u{1}";
        let enc = Json::Str(s.to_string()).encode();
        assert_eq!(Json::parse(&enc).unwrap().as_str().unwrap(), s);
    }

    #[test]
    fn typed_accessors_error_cleanly() {
        let v = Json::parse(r#"{"n": 1.5}"#).unwrap();
        assert!(v.get("n").unwrap().as_usize().is_err());
        assert!(v.get("missing").is_err());
        assert!(v.get("n").unwrap().as_str().is_err());
    }

    #[test]
    fn deterministic_object_order() {
        let v = Json::parse(r#"{"b":1,"a":2}"#).unwrap();
        assert_eq!(v.encode(), r#"{"a":2,"b":1}"#);
    }
}
