//! Scoped-thread data-parallel executor for the runtime kernels.
//!
//! No external deps (the workspace is offline/vendored-only), so this is
//! a thin partition-and-scope helper over `std::thread::scope`: an output
//! buffer of `rows` rows is split into contiguous row ranges, each range
//! handed to one scoped thread together with a caller-provided mutable
//! context slot (per-thread scratch).  Threads never share an output
//! element, so results are **bit-identical for every thread count** as
//! long as the per-element computation itself is deterministic — the
//! invariant the reference-backend kernels are property-tested on.

/// Below this many output elements the partitioned work runs inline on
/// the calling thread: spawn overhead (~tens of µs) would dominate.
pub const MIN_PAR_ELEMS: usize = 8 * 1024;

/// Split `out` (logically `rows` rows of `row_len` elements) into up to
/// `threads` contiguous row chunks and run `f(first_row, chunk, ctx)` on
/// each, in parallel.  `ctx` provides one mutable context slot per chunk
/// (scratch buffers etc.); it must hold at least `threads.min(rows)`
/// items when the parallel path is taken, and at least one item always.
///
/// Falls back to a single inline call when `threads <= 1`, when there is
/// only one row, or when the output is too small to amortize spawning.
pub fn par_rows<C, F>(threads: usize, out: &mut [f32], rows: usize, row_len: usize, ctx: &mut [C], f: F)
where
    C: Send,
    F: Fn(usize, &mut [f32], &mut C) + Sync,
{
    debug_assert_eq!(out.len(), rows * row_len, "out must be rows x row_len");
    let nt = threads.min(rows).max(1);
    if nt <= 1 || out.len() < MIN_PAR_ELEMS {
        f(0, out, &mut ctx[0]);
        return;
    }
    assert!(ctx.len() >= nt, "need one context slot per thread");
    // balanced contiguous partition: the first `extra` chunks get one
    // additional row
    let base = rows / nt;
    let extra = rows % nt;
    std::thread::scope(|s| {
        let mut rest = out;
        let mut ctx_rest = ctx;
        let mut row0 = 0usize;
        for t in 0..nt {
            let take = base + usize::from(t < extra);
            let (chunk, tail) = rest.split_at_mut(take * row_len);
            rest = tail;
            let (slot, ctx_tail) = ctx_rest.split_at_mut(1);
            ctx_rest = ctx_tail;
            let first = row0;
            let fref = &f;
            let slot0 = &mut slot[0];
            s.spawn(move || fref(first, chunk, slot0));
            row0 += take;
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn covers_every_row_exactly_once() {
        // rows*row_len above MIN_PAR_ELEMS so the parallel path runs
        let rows = 130;
        let row_len = 100;
        let mut out = vec![0.0f32; rows * row_len];
        let mut ctx = vec![(); 4];
        par_rows(4, &mut out, rows, row_len, &mut ctx, |row0, chunk, _| {
            for (i, row) in chunk.chunks_exact_mut(row_len).enumerate() {
                for v in row.iter_mut() {
                    *v += (row0 + i) as f32;
                }
            }
        });
        for (r, row) in out.chunks_exact(row_len).enumerate() {
            assert!(row.iter().all(|&v| v == r as f32), "row {r}");
        }
    }

    #[test]
    fn inline_when_single_thread_or_small() {
        let mut out = vec![0.0f32; 16];
        let mut ctx = vec![0u32; 1];
        par_rows(8, &mut out, 4, 4, &mut ctx, |row0, chunk, c| {
            // small output: must run as one inline call
            assert_eq!(row0, 0);
            assert_eq!(chunk.len(), 16);
            *c += 1;
        });
        assert_eq!(ctx[0], 1);
    }

    #[test]
    fn identical_across_thread_counts() {
        let rows = 120;
        let row_len = 90;
        let run = |threads: usize| {
            let mut out = vec![0.0f32; rows * row_len];
            let mut ctx = vec![(); threads.max(1)];
            par_rows(threads, &mut out, rows, row_len, &mut ctx, |row0, chunk, _| {
                for (i, row) in chunk.chunks_exact_mut(row_len).enumerate() {
                    let r = row0 + i;
                    for (j, v) in row.iter_mut().enumerate() {
                        *v = ((r * 31 + j) as f32 * 0.37).sin();
                    }
                }
            });
            out
        };
        let one = run(1);
        assert_eq!(one, run(2));
        assert_eq!(one, run(3));
        assert_eq!(one, run(7));
    }

    #[test]
    fn per_thread_context_is_private() {
        let rows = 64;
        let row_len = 256; // 16k elems -> parallel path
        let mut out = vec![0.0f32; rows * row_len];
        let mut ctx: Vec<Vec<usize>> = vec![Vec::new(); 4];
        par_rows(4, &mut out, rows, row_len, &mut ctx, |row0, chunk, seen| {
            seen.push(row0);
            seen.push(chunk.len() / row_len);
        });
        let total: usize = ctx.iter().map(|c| c.get(1).copied().unwrap_or(0)).sum();
        assert_eq!(total, rows, "chunks partition the rows");
    }
}
