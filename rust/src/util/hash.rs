//! Tiny FNV-1a (64-bit) fold, shared by the reference backend's weight
//! seeding and the serving batch executor's tensor digests so the
//! constants live in one place.

/// FNV-1a over a word stream.
pub fn fnv1a<I: IntoIterator<Item = u64>>(words: I) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for w in words {
        h = (h ^ w).wrapping_mul(0x1000_0000_01b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_input_sensitive() {
        let a = fnv1a([1, 2, 3]);
        assert_eq!(a, fnv1a([1, 2, 3]));
        assert_ne!(a, fnv1a([1, 2, 4]));
        assert_ne!(a, fnv1a([3, 2, 1]), "order matters");
        assert_ne!(fnv1a([]), fnv1a([0]), "absorbing a zero word still mixes");
    }
}
