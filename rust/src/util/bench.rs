//! Mini criterion: a statistics-aware micro/macro benchmark harness.
//!
//! criterion is unavailable offline, so `benches/*.rs` (harness = false)
//! use this: warmup, adaptive iteration count, median/p5/p95 over sample
//! batches, and a one-line report.  `cargo bench` filters by substring
//! argument just like criterion does.

use std::time::{Duration, Instant};

use crate::util::stats;

/// Harness configuration.
#[derive(Debug, Clone)]
pub struct BenchConfig {
    /// Target measurement time per benchmark.
    pub measure: Duration,
    /// Warmup time before sampling.
    pub warmup: Duration,
    /// Number of sample batches the measurement is divided into.
    pub samples: usize,
}

impl Default for BenchConfig {
    fn default() -> Self {
        BenchConfig {
            measure: Duration::from_millis(800),
            warmup: Duration::from_millis(200),
            samples: 20,
        }
    }
}

/// One benchmark result.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub median_ns: f64,
    pub p5_ns: f64,
    pub p95_ns: f64,
    pub iters: u64,
}

impl BenchResult {
    pub fn line(&self) -> String {
        format!(
            "{:48} {:>12}  [{} .. {}]  ({} iters)",
            self.name,
            fmt_ns(self.median_ns),
            fmt_ns(self.p5_ns),
            fmt_ns(self.p95_ns),
            self.iters
        )
    }
}

fn fmt_ns(ns: f64) -> String {
    if ns >= 1e9 {
        format!("{:.3} s", ns / 1e9)
    } else if ns >= 1e6 {
        format!("{:.3} ms", ns / 1e6)
    } else if ns >= 1e3 {
        format!("{:.3} µs", ns / 1e3)
    } else {
        format!("{ns:.0} ns")
    }
}

/// Top-level runner: owns the filter (from `cargo bench -- <filter>` args)
/// and collects results.
pub struct Bencher {
    config: BenchConfig,
    filter: Option<String>,
    pub results: Vec<BenchResult>,
}

impl Bencher {
    /// Build from env args (skips the `--bench` flag cargo passes).
    pub fn from_env() -> Self {
        let filter = std::env::args()
            .skip(1)
            .find(|a| !a.starts_with("--") && !a.is_empty());
        Bencher { config: BenchConfig::default(), filter, results: Vec::new() }
    }

    pub fn with_config(mut self, config: BenchConfig) -> Self {
        self.config = config;
        self
    }

    fn matches(&self, name: &str) -> bool {
        self.filter.as_ref().map(|f| name.contains(f)).unwrap_or(true)
    }

    /// Benchmark a closure; the closure's return value is black-boxed.
    pub fn bench<T, F: FnMut() -> T>(&mut self, name: &str, mut f: F) {
        if !self.matches(name) {
            return;
        }
        // Warmup + estimate cost of one call.
        let warm_start = Instant::now();
        let mut calls = 0u64;
        while warm_start.elapsed() < self.config.warmup || calls < 3 {
            std::hint::black_box(f());
            calls += 1;
            if calls > 1_000_000 {
                break;
            }
        }
        let per_call = warm_start.elapsed().as_nanos() as f64 / calls as f64;
        // Batch size so that one sample ≈ measure/samples.
        let sample_ns = self.config.measure.as_nanos() as f64 / self.config.samples as f64;
        let batch = ((sample_ns / per_call.max(1.0)).ceil() as u64).max(1);
        let mut samples_ns: Vec<f64> = Vec::with_capacity(self.config.samples);
        let mut total_iters = 0u64;
        for _ in 0..self.config.samples {
            let t0 = Instant::now();
            for _ in 0..batch {
                std::hint::black_box(f());
            }
            samples_ns.push(t0.elapsed().as_nanos() as f64 / batch as f64);
            total_iters += batch;
        }
        let result = BenchResult {
            name: name.to_string(),
            median_ns: stats::median(&samples_ns),
            p5_ns: stats::quantile(&samples_ns, 0.05),
            p95_ns: stats::quantile(&samples_ns, 0.95),
            iters: total_iters,
        };
        println!("{}", result.line());
        self.results.push(result);
    }

    /// Run a *macro* experiment once (experiment harnesses that already do
    /// their own repetition + reporting); timed and recorded for the log.
    pub fn run_once<F: FnOnce()>(&mut self, name: &str, f: F) {
        if !self.matches(name) {
            return;
        }
        println!("=== {name} ===");
        let t0 = Instant::now();
        f();
        let ns = t0.elapsed().as_nanos() as f64;
        println!("--- {name}: completed in {}\n", fmt_ns(ns));
        self.results.push(BenchResult {
            name: name.to_string(),
            median_ns: ns,
            p5_ns: ns,
            p95_ns: ns,
            iters: 1,
        });
    }

    /// Final summary block (printed at the end of each bench binary).
    pub fn finish(&self) {
        println!("\n{} benchmark(s) run", self.results.len());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick() -> BenchConfig {
        BenchConfig {
            measure: Duration::from_millis(20),
            warmup: Duration::from_millis(5),
            samples: 5,
        }
    }

    #[test]
    fn bench_measures_something() {
        let mut b = Bencher { config: quick(), filter: None, results: Vec::new() };
        b.bench("noop-ish", || std::hint::black_box(1 + 1));
        assert_eq!(b.results.len(), 1);
        assert!(b.results[0].median_ns >= 0.0);
        assert!(b.results[0].iters > 0);
    }

    #[test]
    fn filter_skips() {
        let mut b = Bencher {
            config: quick(),
            filter: Some("match-me".into()),
            results: Vec::new(),
        };
        b.bench("other", || 1);
        assert!(b.results.is_empty());
        b.bench("yes-match-me-yes", || 1);
        assert_eq!(b.results.len(), 1);
    }

    #[test]
    fn run_once_records() {
        let mut b = Bencher { config: quick(), filter: None, results: Vec::new() };
        b.run_once("macro", || std::thread::sleep(Duration::from_millis(1)));
        assert_eq!(b.results.len(), 1);
        assert!(b.results[0].median_ns >= 1e6);
    }
}
