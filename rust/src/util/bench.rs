//! Mini criterion: a statistics-aware micro/macro benchmark harness.
//!
//! criterion is unavailable offline, so `benches/*.rs` (harness = false)
//! use this: warmup, adaptive iteration count, median/p5/p95 over sample
//! batches, and a one-line report.  `cargo bench` filters by substring
//! argument just like criterion does.
//!
//! **Machine-readable trajectory**: `--json <path>` (or the
//! `DYNASPLIT_BENCH_JSON` env var) appends this run's results to a JSON
//! trajectory file — `BENCH_runtime.json` at the repo root tracks the
//! runtime hot path across PRs (`cargo bench --bench micro -- --json
//! BENCH_runtime.json`).  `DYNASPLIT_BENCH_QUICK=1` shrinks
//! measure/warmup times for CI smoke runs where the harness itself is
//! under test, not the numbers.

use std::time::{Duration, Instant};

use crate::util::json::Json;
use crate::util::stats;

/// Harness configuration.
#[derive(Debug, Clone)]
pub struct BenchConfig {
    /// Target measurement time per benchmark.
    pub measure: Duration,
    /// Warmup time before sampling.
    pub warmup: Duration,
    /// Number of sample batches the measurement is divided into.
    pub samples: usize,
}

impl Default for BenchConfig {
    fn default() -> Self {
        BenchConfig {
            measure: Duration::from_millis(800),
            warmup: Duration::from_millis(200),
            samples: 20,
        }
    }
}

impl BenchConfig {
    /// CI smoke mode (`DYNASPLIT_BENCH_QUICK=1`): exercises every bench
    /// case and the JSON path in seconds, without statistical ambition.
    pub fn quick() -> BenchConfig {
        BenchConfig {
            measure: Duration::from_millis(30),
            warmup: Duration::from_millis(5),
            samples: 5,
        }
    }
}

/// One benchmark result.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub median_ns: f64,
    pub p5_ns: f64,
    pub p95_ns: f64,
    pub iters: u64,
}

impl BenchResult {
    pub fn line(&self) -> String {
        format!(
            "{:48} {:>12}  [{} .. {}]  ({} iters)",
            self.name,
            fmt_ns(self.median_ns),
            fmt_ns(self.p5_ns),
            fmt_ns(self.p95_ns),
            self.iters
        )
    }
}

fn fmt_ns(ns: f64) -> String {
    if ns >= 1e9 {
        format!("{:.3} s", ns / 1e9)
    } else if ns >= 1e6 {
        format!("{:.3} ms", ns / 1e6)
    } else if ns >= 1e3 {
        format!("{:.3} µs", ns / 1e3)
    } else {
        format!("{ns:.0} ns")
    }
}

/// Top-level runner: owns the filter (from `cargo bench -- <filter>` args)
/// and collects results.
pub struct Bencher {
    config: BenchConfig,
    filter: Option<String>,
    /// Trajectory file this run's results are appended to on `finish`.
    json_path: Option<String>,
    pub results: Vec<BenchResult>,
}

impl Bencher {
    /// Build from env args (skips the `--bench` flag cargo passes,
    /// consumes `--json <path>`; `DYNASPLIT_BENCH_JSON` and
    /// `DYNASPLIT_BENCH_QUICK` env vars are honored too).
    pub fn from_env() -> Self {
        let mut filter = None;
        let mut json_path = std::env::var("DYNASPLIT_BENCH_JSON").ok();
        let mut args = std::env::args().skip(1);
        while let Some(a) = args.next() {
            if a == "--json" {
                json_path = args.next();
            } else if !a.starts_with("--") && !a.is_empty() && filter.is_none() {
                filter = Some(a);
            }
        }
        let config = if std::env::var_os("DYNASPLIT_BENCH_QUICK").is_some() {
            BenchConfig::quick()
        } else {
            BenchConfig::default()
        };
        Bencher { config, filter, json_path, results: Vec::new() }
    }

    pub fn with_config(mut self, config: BenchConfig) -> Self {
        self.config = config;
        self
    }

    fn matches(&self, name: &str) -> bool {
        self.filter.as_ref().map(|f| name.contains(f)).unwrap_or(true)
    }

    /// Benchmark a closure; the closure's return value is black-boxed.
    pub fn bench<T, F: FnMut() -> T>(&mut self, name: &str, mut f: F) {
        if !self.matches(name) {
            return;
        }
        // Warmup + estimate cost of one call.
        let warm_start = Instant::now();
        let mut calls = 0u64;
        while warm_start.elapsed() < self.config.warmup || calls < 3 {
            std::hint::black_box(f());
            calls += 1;
            if calls > 1_000_000 {
                break;
            }
        }
        let per_call = warm_start.elapsed().as_nanos() as f64 / calls as f64;
        // Batch size so that one sample ≈ measure/samples.
        let sample_ns = self.config.measure.as_nanos() as f64 / self.config.samples as f64;
        let batch = ((sample_ns / per_call.max(1.0)).ceil() as u64).max(1);
        let mut samples_ns: Vec<f64> = Vec::with_capacity(self.config.samples);
        let mut total_iters = 0u64;
        for _ in 0..self.config.samples {
            let t0 = Instant::now();
            for _ in 0..batch {
                std::hint::black_box(f());
            }
            samples_ns.push(t0.elapsed().as_nanos() as f64 / batch as f64);
            total_iters += batch;
        }
        let result = BenchResult {
            name: name.to_string(),
            median_ns: stats::median(&samples_ns),
            p5_ns: stats::quantile(&samples_ns, 0.05),
            p95_ns: stats::quantile(&samples_ns, 0.95),
            iters: total_iters,
        };
        println!("{}", result.line());
        self.results.push(result);
    }

    /// Run a *macro* experiment once (experiment harnesses that already do
    /// their own repetition + reporting); timed and recorded for the log.
    pub fn run_once<F: FnOnce()>(&mut self, name: &str, f: F) {
        if !self.matches(name) {
            return;
        }
        println!("=== {name} ===");
        let t0 = Instant::now();
        f();
        let ns = t0.elapsed().as_nanos() as f64;
        println!("--- {name}: completed in {}\n", fmt_ns(ns));
        self.results.push(BenchResult {
            name: name.to_string(),
            median_ns: ns,
            p5_ns: ns,
            p95_ns: ns,
            iters: 1,
        });
    }

    /// This run as a JSON object (config + per-case results).
    fn run_json(&self) -> Json {
        Json::obj(vec![
            ("measure_ms", Json::num(self.config.measure.as_secs_f64() * 1000.0)),
            ("samples", Json::num(self.config.samples as f64)),
            (
                "quick",
                Json::Bool(self.config.measure < BenchConfig::default().measure),
            ),
            (
                "results",
                Json::arr(self.results.iter().map(|r| {
                    Json::obj(vec![
                        ("name", Json::str(r.name.clone())),
                        ("median_ns", Json::num(r.median_ns)),
                        ("p5_ns", Json::num(r.p5_ns)),
                        ("p95_ns", Json::num(r.p95_ns)),
                        ("iters", Json::num(r.iters as f64)),
                    ])
                })),
            ),
        ])
    }

    /// Append this run to the JSON trajectory at `path` (created with a
    /// note when missing or malformed).  Each run is one entry in the
    /// `runs` array, so a file tracked in git records the perf
    /// trajectory across PRs.
    pub fn write_json(&self, path: &str) -> anyhow::Result<()> {
        let fresh = || {
            Json::obj(vec![
                (
                    "note",
                    Json::str(
                        "Perf trajectory of the runtime hot path; append runs with \
                         `cargo bench --bench micro -- --json <this file>`.",
                    ),
                ),
                ("runs", Json::Arr(Vec::new())),
            ])
        };
        let mut doc = std::fs::read_to_string(path)
            .ok()
            .and_then(|text| Json::parse(&text).ok())
            .filter(|j| matches!(j.opt("runs"), Some(Json::Arr(_))))
            .unwrap_or_else(fresh);
        if let Json::Obj(m) = &mut doc {
            if let Some(Json::Arr(runs)) = m.get_mut("runs") {
                runs.push(self.run_json());
            }
        }
        std::fs::write(path, doc.encode())?;
        Ok(())
    }

    /// Ratio of two recorded medians (`a` over `b`), e.g. the
    /// naive-vs-GEMM speedup; `None` until both cases ran.
    pub fn speedup(&self, slow: &str, fast: &str) -> Option<f64> {
        let median = |name: &str| {
            self.results
                .iter()
                .find(|r| r.name == name)
                .map(|r| r.median_ns)
        };
        match (median(slow), median(fast)) {
            (Some(s), Some(f)) if f > 0.0 => Some(s / f),
            _ => None,
        }
    }

    /// Final summary block (printed at the end of each bench binary);
    /// appends to the JSON trajectory when one was requested.
    pub fn finish(&self) {
        println!("\n{} benchmark(s) run", self.results.len());
        if let Some(path) = &self.json_path {
            match self.write_json(path) {
                Ok(()) => println!("bench results appended to {path}"),
                Err(e) => eprintln!("failed to write bench JSON {path}: {e:#}"),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick() -> BenchConfig {
        BenchConfig {
            measure: Duration::from_millis(20),
            warmup: Duration::from_millis(5),
            samples: 5,
        }
    }

    fn bencher(filter: Option<String>) -> Bencher {
        Bencher { config: quick(), filter, json_path: None, results: Vec::new() }
    }

    #[test]
    fn bench_measures_something() {
        let mut b = bencher(None);
        b.bench("noop-ish", || std::hint::black_box(1 + 1));
        assert_eq!(b.results.len(), 1);
        assert!(b.results[0].median_ns >= 0.0);
        assert!(b.results[0].iters > 0);
    }

    #[test]
    fn filter_skips() {
        let mut b = bencher(Some("match-me".into()));
        b.bench("other", || 1);
        assert!(b.results.is_empty());
        b.bench("yes-match-me-yes", || 1);
        assert_eq!(b.results.len(), 1);
    }

    #[test]
    fn run_once_records() {
        let mut b = bencher(None);
        b.run_once("macro", || std::thread::sleep(Duration::from_millis(1)));
        assert_eq!(b.results.len(), 1);
        assert!(b.results[0].median_ns >= 1e6);
    }

    #[test]
    fn json_trajectory_appends_runs() {
        let path = std::env::temp_dir().join(format!(
            "dynasplit_bench_{}_{}.json",
            std::process::id(),
            std::time::SystemTime::now()
                .duration_since(std::time::UNIX_EPOCH)
                .unwrap()
                .as_nanos()
        ));
        let path_str = path.to_str().unwrap();
        let mut b = bencher(None);
        b.bench("case_a", || std::hint::black_box(2 * 2));
        b.write_json(path_str).unwrap();
        b.write_json(path_str).unwrap();
        let doc = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        let runs = doc.get("runs").unwrap().as_arr().unwrap();
        assert_eq!(runs.len(), 2, "each write appends one run");
        let results = runs[0].get("results").unwrap().as_arr().unwrap();
        assert_eq!(results[0].get("name").unwrap().as_str().unwrap(), "case_a");
        assert!(results[0].get("median_ns").unwrap().as_f64().unwrap() >= 0.0);
        assert!(runs[0].get("quick").unwrap().as_bool().unwrap(), "test config is quick");
        // malformed file is replaced, not crashed on
        std::fs::write(&path, "not json").unwrap();
        b.write_json(path_str).unwrap();
        let doc = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(doc.get("runs").unwrap().as_arr().unwrap().len(), 1);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn speedup_is_ratio_of_medians() {
        let mut b = bencher(None);
        b.results.push(BenchResult {
            name: "slow".into(),
            median_ns: 800.0,
            p5_ns: 700.0,
            p95_ns: 900.0,
            iters: 10,
        });
        b.results.push(BenchResult {
            name: "fast".into(),
            median_ns: 200.0,
            p5_ns: 150.0,
            p95_ns: 260.0,
            iters: 10,
        });
        assert_eq!(b.speedup("slow", "fast"), Some(4.0));
        assert_eq!(b.speedup("slow", "missing"), None);
    }
}
