//! Foundation utilities, hand-rolled because the offline build
//! environment lacks `rand`/`serde`/`clap`/`criterion` (see the
//! Cargo.toml note): the workspace must build with **zero registry
//! access**, so every substitute below is dependency-free and only as
//! big as the crate actually needs.
//!
//! | module | stands in for | used by |
//! |--------|---------------|---------|
//! | [`rng`] | `rand` (PCG-32 streams, Weibull/lognormal draws) | workload gen, solver, simulator jitter |
//! | [`stats`] | quantiles/means/medians | metrics, drift windows, reports |
//! | [`json`] | `serde_json` (parse + emit) | pareto sets, manifests, bench trajectories |
//! | [`cli`] | `clap` (declarative flags + `--help`) | `main.rs` subcommands, examples, benches |
//! | [`table`] | tabular stdout + CSV emission | every experiment report |
//! | [`bench`] | `criterion` (timed cases, JSON trajectory, enforce floors) | `benches/micro.rs`, CI perf gate |
//! | [`hash`] | `fnv` (FNV-1a over `u64` streams) | layer seeds, tensor digests, `ConfigSet::digest` |
//! | [`parallel`] | `rayon`-lite scoped row partitioning | reference-backend GEMM threading |
//! | [`sync`] | poison-recovering lock helpers (shed, don't crash) | queue, telemetry, store, batch log |
//!
//! Determinism is the common contract: every RNG is an explicit seeded
//! stream ([`rng::Pcg32::new(seed, stream)`](rng::Pcg32)), so every
//! workload, search, and simulated trial replays bit-identically given
//! its seed — the property the serving pipeline's baseline-equivalence
//! tests and the kernel equivalence suites build on.

pub mod rng;
pub mod stats;
pub mod json;
pub mod cli;
pub mod table;
pub mod bench;
pub mod hash;
pub mod parallel;
pub mod sync;
