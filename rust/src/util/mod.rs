//! Foundation utilities, hand-rolled because the offline build environment
//! lacks `rand`/`serde`/`clap`/`criterion` (see Cargo.toml note).

pub mod rng;
pub mod stats;
pub mod json;
pub mod cli;
pub mod table;
pub mod bench;
pub mod hash;
pub mod parallel;
