//! Deterministic PRNG (PCG-XSH-RR 64/32) + distribution samplers.
//!
//! Every stochastic component in DynaSplit (workload QoS draws, NSGA-III
//! genetic operators, simulator measurement noise) takes an explicit
//! `Pcg32` so experiments are reproducible from a seed recorded in the
//! experiment logs.

/// PCG-XSH-RR 64/32: small, fast, statistically solid. Reference:
/// O'Neill, "PCG: A Family of Simple Fast Space-Efficient Statistically
/// Good Algorithms for Random Number Generation" (2014).
#[derive(Debug, Clone)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

const PCG_MULT: u64 = 6364136223846793005;

impl Pcg32 {
    /// Seed with an arbitrary (seed, stream) pair. Different streams are
    /// independent sequences even for equal seeds.
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg32 { state: 0, inc: (stream << 1) | 1 };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    /// Convenience: stream 0.
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0)
    }

    /// Derive a child RNG (used to give each request / trial / island its
    /// own independent stream).
    pub fn fork(&mut self, stream: u64) -> Pcg32 {
        Pcg32::new(self.next_u64(), stream.wrapping_mul(2654435761).wrapping_add(1))
    }

    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n) (Lemire-style rejection-free for our needs;
    /// modulo bias is < 2^-32 for all n we use, but we reject anyway).
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "below(0)");
        let threshold = n.wrapping_neg() % n;
        loop {
            let r = self.next_u64();
            if r >= threshold {
                return r % n;
            }
        }
    }

    /// Uniform integer in [lo, hi] inclusive.
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi);
        lo + self.below((hi - lo + 1) as u64) as i64
    }

    /// Uniform in [lo, hi).
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Bernoulli trial.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// N(mu, sigma).
    pub fn gaussian(&mut self, mu: f64, sigma: f64) -> f64 {
        mu + sigma * self.normal()
    }

    /// Log-normal with given *location/scale of the underlying normal*.
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        self.gaussian(mu, sigma).exp()
    }

    /// Weibull(shape k, scale lambda). With k = 1 this reduces to
    /// Exponential(lambda), which is how the paper draws QoS levels (§6.2.1).
    pub fn weibull(&mut self, shape: f64, scale: f64) -> f64 {
        let u = 1.0 - self.f64(); // in (0, 1]
        scale * (-u.ln()).powf(1.0 / shape)
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Pick a uniformly random element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u64) as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let mut a = Pcg32::seeded(42);
        let mut b = Pcg32::seeded(42);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn streams_differ() {
        let mut a = Pcg32::new(42, 1);
        let mut b = Pcg32::new(42, 2);
        let same = (0..64).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Pcg32::seeded(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Pcg32::seeded(3);
        let mut seen = [false; 7];
        for _ in 0..1_000 {
            seen[r.below(7) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg32::seeded(11);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn weibull_shape1_is_exponential() {
        // mean of Exp(lambda=2) is 2.
        let mut r = Pcg32::seeded(13);
        let n = 50_000;
        let mean = (0..n).map(|_| r.weibull(1.0, 2.0)).sum::<f64>() / n as f64;
        assert!((mean - 2.0).abs() < 0.05, "mean {mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg32::seeded(17);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn fork_decorrelates() {
        let mut root = Pcg32::seeded(5);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        let same = (0..64).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }
}
