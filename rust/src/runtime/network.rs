//! Whole-network runtime: compose per-layer executables into arbitrary
//! head/tail splits, with the int8 (edge-TPU) variants for VGG16 heads.
//!
//! Layers come from any [`InferenceBackend`] — the PJRT engine when
//! artifacts and XLA are available, the pure-Rust reference interpreter
//! otherwise — so the same head/tail composition logic serves both.

use std::path::Path;

use anyhow::{bail, Context, Result};

use super::arena::TensorArena;
use super::backend::{InferenceBackend, LayerExecutable, LayerSpec};
use crate::model::manifest::{LayerEntry, Manifest, NetworkEntry};
use crate::space::Network;

/// All instantiated executables for one network.
pub struct NetworkRuntime {
    pub net: Network,
    pub batch: usize,
    fp32: Vec<Box<dyn LayerExecutable>>,
    /// int8 variant per layer (None for non-quantizable / ViT layers —
    /// those run the fp32 executable on the TPU path too, matching how
    /// LiteRT falls back to the CPU delegate between fused ops).
    int8: Vec<Option<Box<dyn LayerExecutable>>>,
    pub load_ms: f64,
}

impl NetworkRuntime {
    /// Instantiate every layer of `net` listed in the manifest.
    pub fn load(
        backend: &dyn InferenceBackend,
        manifest: &Manifest,
        net: Network,
    ) -> Result<NetworkRuntime> {
        let entry: &NetworkEntry = manifest.network(net);
        Self::from_layers(backend, net, manifest.batch, &entry.layers, Some(manifest.dir.as_path()))
    }

    /// Instantiate from raw layer entries — the manifest-free path used
    /// by synthetic-network tests and tools.  `artifact_dir` resolves
    /// each entry's relative artifact paths for backends that compile
    /// from disk; interpreter backends run without it.
    pub fn from_layers(
        backend: &dyn InferenceBackend,
        net: Network,
        batch: usize,
        layers: &[LayerEntry],
        artifact_dir: Option<&Path>,
    ) -> Result<NetworkRuntime> {
        let sw = crate::serve::clock::Stopwatch::start();
        let mut fp32: Vec<Box<dyn LayerExecutable>> = Vec::with_capacity(layers.len());
        let mut int8: Vec<Option<Box<dyn LayerExecutable>>> = Vec::with_capacity(layers.len());
        for layer in layers {
            let exec = backend
                .load_layer(&LayerSpec {
                    entry: layer,
                    batch,
                    artifact: artifact_dir.map(|d| d.join(&layer.fp32)),
                    quantized: false,
                })
                .with_context(|| format!("{} layer {}", net.name(), layer.index))?;
            fp32.push(exec);
            int8.push(match &layer.int8 {
                Some(rel) => Some(
                    backend
                        .load_layer(&LayerSpec {
                            entry: layer,
                            batch,
                            artifact: artifact_dir.map(|d| d.join(rel)),
                            quantized: true,
                        })
                        .with_context(|| format!("{} int8 layer {}", net.name(), layer.index))?,
                ),
                None => None,
            });
        }
        Ok(NetworkRuntime {
            net,
            batch,
            fp32,
            int8,
            load_ms: sw.elapsed_ms(),
        })
    }

    pub fn num_layers(&self) -> usize {
        self.fp32.len()
    }

    /// Input elements of a single image at layer 0 (the network's input
    /// width) — what batch-packing callers multiply by their batch size.
    pub fn input_elems_per_image(&self) -> usize {
        self.fp32
            .first()
            .map(|l| l.in_elems() / self.batch.max(1))
            .unwrap_or(0)
    }

    fn layer(&self, i: usize, quantized: bool) -> &dyn LayerExecutable {
        if quantized {
            self.int8[i].as_deref().unwrap_or_else(|| &*self.fp32[i])
        } else {
            &*self.fp32[i]
        }
    }

    /// Advance the arena's front activation through layers `[from, to)`
    /// in place (ping-pong between the arena's two buffers: zero
    /// allocations after warmup).
    fn advance(&self, from: usize, to: usize, quantized: bool, arena: &mut TensorArena) -> Result<()> {
        if from > to || to > self.num_layers() {
            bail!("bad layer range {from}..{to} (L = {})", self.num_layers());
        }
        for i in from..to {
            let (x, out) = arena.pair();
            self.layer(i, quantized)
                .run_into(x, out)
                .with_context(|| format!("{} layer {i}", self.net.name()))?;
            arena.swap();
        }
        Ok(())
    }

    /// Run layers `[from, to)` sequentially on a flat activation batch,
    /// reusing `arena`'s buffers for every intermediate activation.
    /// `quantized` selects the int8 variants (edge-TPU path).  The
    /// returned slice borrows the arena and stays valid until its next
    /// use — hot callers keep one arena per session and copy nothing.
    pub fn run_range_in<'a>(
        &self,
        from: usize,
        to: usize,
        quantized: bool,
        input: &[f32],
        arena: &'a mut TensorArena,
    ) -> Result<&'a [f32]> {
        arena.load(input);
        self.advance(from, to, quantized, arena)?;
        Ok(arena.front())
    }

    /// Arena-reusing head segment: layers [0, k).
    pub fn run_head_in<'a>(
        &self,
        k: usize,
        tpu: bool,
        input: &[f32],
        arena: &'a mut TensorArena,
    ) -> Result<&'a [f32]> {
        self.run_range_in(0, k, tpu, input, arena)
    }

    /// Arena-reusing full forward with the head quantized up to
    /// `quant_upto` — one buffer pair for both segments.
    pub fn run_full_in<'a>(
        &self,
        quant_upto: usize,
        input: &[f32],
        arena: &'a mut TensorArena,
    ) -> Result<&'a [f32]> {
        arena.load(input);
        self.advance(0, quant_upto, true, arena)?;
        self.advance(quant_upto, self.num_layers(), false, arena)?;
        Ok(arena.front())
    }

    /// Run layers `[from, to)` on a flat activation batch.  Convenience
    /// wrapper allocating a fresh arena; loops and serving paths use
    /// [`NetworkRuntime::run_range_in`] to reuse buffers.
    pub fn run_range(
        &self,
        from: usize,
        to: usize,
        quantized: bool,
        input: &[f32],
    ) -> Result<Vec<f32>> {
        let mut arena = TensorArena::new();
        self.run_range_in(from, to, quantized, input, &mut arena)?;
        Ok(arena.into_front())
    }

    /// Head segment: layers [0, k), quantized when the TPU path is active.
    pub fn run_head(&self, k: usize, tpu: bool, input: &[f32]) -> Result<Vec<f32>> {
        self.run_range(0, k, tpu, input)
    }

    /// Tail segment: layers [k, L), always fp32 (cloud side).
    pub fn run_tail(&self, k: usize, input: &[f32]) -> Result<Vec<f32>> {
        self.run_range(k, self.num_layers(), false, input)
    }

    /// Full forward with the head quantized up to `quant_upto`.
    pub fn run_full(&self, quant_upto: usize, input: &[f32]) -> Result<Vec<f32>> {
        let mut arena = TensorArena::new();
        self.run_full_in(quant_upto, input, &mut arena)?;
        Ok(arena.into_front())
    }

    /// Argmax class per image of a `[batch, classes]` probability matrix.
    pub fn classify(probs: &[f32], classes: usize) -> Vec<usize> {
        probs
            .chunks_exact(classes)
            .map(|row| {
                row.iter()
                    .enumerate()
                    .max_by(|a, b| a.1.total_cmp(b.1))
                    .map(|(i, _)| i)
                    .unwrap_or(0)
            })
            .collect()
    }
}

/// Tail executor over network runtimes, used by the cloud service loop
/// (`transport::cloud`).  Constructed *inside* the cloud node's thread —
/// layer executables are not `Send` (PJRT handles are thread-local), and
/// the paper's cloud node owns its own runtime anyway (the tail networks
/// are loaded cloud-side, §4.3.2).
pub struct RuntimeTailExecutor {
    pub vgg: NetworkRuntime,
    pub vit: NetworkRuntime,
}

impl RuntimeTailExecutor {
    /// Build the configured backend + both network runtimes (cloud-node
    /// startup).
    pub fn load(manifest: &Manifest) -> Result<RuntimeTailExecutor> {
        let backend = super::backend::default_backend()?;
        Ok(RuntimeTailExecutor {
            vgg: NetworkRuntime::load(backend.as_ref(), manifest, Network::Vgg16)?,
            vit: NetworkRuntime::load(backend.as_ref(), manifest, Network::Vit)?,
        })
    }
}

impl crate::transport::cloud::TailExecutor for RuntimeTailExecutor {
    fn execute_tail(
        &self,
        network: &str,
        split: usize,
        _gpu: bool,
        batch: &[f32],
    ) -> Result<Vec<f32>> {
        let rt = match Network::parse(network)? {
            Network::Vgg16 => &self.vgg,
            Network::Vit => &self.vit,
        };
        rt.run_tail(split, batch)
    }
}

/// Spawn a cloud-node thread: it loads its own runtimes from `manifest`
/// and serves the given endpoint until shutdown.  Returns the join handle
/// carrying the service statistics.
pub fn spawn_cloud_node(
    manifest: Manifest,
    endpoint: crate::transport::channel::Endpoint,
    timeout: std::time::Duration,
) -> std::thread::JoinHandle<Result<crate::transport::cloud::ServeStats>> {
    // dslint::allow(no-thread-spawn): the cloud node's lifetime is tied to
    // the RealSplitExecutor that owns this handle (joined in shutdown()),
    // not to any lexical scope — see DESIGN.md §13
    std::thread::spawn(move || {
        let executor = RuntimeTailExecutor::load(&manifest)?;
        crate::transport::cloud::serve(endpoint, &executor, timeout)
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::manifest::LayerEntry;
    use crate::runtime::reference::ReferenceBackend;

    fn tiny_runtime() -> NetworkRuntime {
        let layers = vec![
            LayerEntry::synthetic(0, vec![6, 6, 2], vec![6, 6, 4]),
            LayerEntry::synthetic(1, vec![6, 6, 4], vec![3, 3, 4]),
            LayerEntry::synthetic(2, vec![3, 3, 4], vec![10]),
        ];
        NetworkRuntime::from_layers(&ReferenceBackend::new(), Network::Vgg16, 2, &layers, None)
            .expect("reference runtime")
    }

    #[test]
    fn arena_forward_matches_allocating_forward() {
        let rt = tiny_runtime();
        let x: Vec<f32> = (0..2 * 72).map(|i| (i as f32 * 0.21).cos()).collect();
        let want = rt.run_range(0, 3, false, &x).unwrap();
        let mut arena = TensorArena::new();
        let got = rt.run_range_in(0, 3, false, &x, &mut arena).unwrap();
        assert_eq!(got, want.as_slice());
        assert_eq!(rt.run_full(0, &x).unwrap(), want);
        let mut arena2 = TensorArena::new();
        assert_eq!(rt.run_full_in(0, &x, &mut arena2).unwrap(), want.as_slice());
    }

    #[test]
    fn arena_steady_state_is_zero_alloc() {
        let rt = tiny_runtime();
        let x: Vec<f32> = (0..2 * 72).map(|i| (i as f32 * 0.13).sin()).collect();
        let mut arena = TensorArena::new();
        // warmup grows the buffers to the widest activation
        rt.run_range_in(0, 3, false, &x, &mut arena).unwrap();
        rt.run_range_in(0, 3, false, &x, &mut arena).unwrap();
        let cap = arena.capacity();
        for _ in 0..4 {
            rt.run_range_in(0, 3, false, &x, &mut arena).unwrap();
            assert_eq!(arena.capacity(), cap, "steady-state forward must not grow the arena");
        }
    }

    #[test]
    fn empty_range_echoes_the_input() {
        let rt = tiny_runtime();
        let x: Vec<f32> = (0..2 * 72).map(|i| i as f32).collect();
        assert_eq!(rt.run_range(1, 1, false, &x).unwrap(), x);
    }

    #[test]
    fn bad_range_is_rejected() {
        let rt = tiny_runtime();
        assert!(rt.run_range(2, 1, false, &[0.0; 144]).is_err());
        assert!(rt.run_range(0, 9, false, &[0.0; 144]).is_err());
    }

    #[test]
    fn classify_argmax() {
        let probs = [0.1, 0.7, 0.2, /*img2*/ 0.5, 0.2, 0.3];
        assert_eq!(NetworkRuntime::classify(&probs, 3), vec![1, 0]);
    }

    #[test]
    fn classify_handles_short_tail() {
        // trailing partial row is ignored by chunks_exact
        let probs = [0.9, 0.1, 0.5];
        assert_eq!(NetworkRuntime::classify(&probs, 2), vec![0]);
    }

    #[test]
    fn classify_survives_nan_rows() {
        // total_cmp ranks NaN above every number, so a NaN poisons only
        // its own row's argmax instead of panicking the whole batch.
        let probs = [0.1, f32::NAN, 0.2, /*img2*/ 0.9, 0.05, 0.05];
        assert_eq!(NetworkRuntime::classify(&probs, 3), vec![1, 0]);
    }
}
