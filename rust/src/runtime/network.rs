//! Whole-network runtime: compose per-layer executables into arbitrary
//! head/tail splits, with the int8 (edge-TPU) variants for VGG16 heads.
//!
//! Layers come from any [`InferenceBackend`] — the PJRT engine when
//! artifacts and XLA are available, the pure-Rust reference interpreter
//! otherwise — so the same head/tail composition logic serves both.

use std::path::Path;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use super::backend::{InferenceBackend, LayerExecutable, LayerSpec};
use crate::model::manifest::{LayerEntry, Manifest, NetworkEntry};
use crate::space::Network;

/// All instantiated executables for one network.
pub struct NetworkRuntime {
    pub net: Network,
    pub batch: usize,
    fp32: Vec<Box<dyn LayerExecutable>>,
    /// int8 variant per layer (None for non-quantizable / ViT layers —
    /// those run the fp32 executable on the TPU path too, matching how
    /// LiteRT falls back to the CPU delegate between fused ops).
    int8: Vec<Option<Box<dyn LayerExecutable>>>,
    pub load_ms: f64,
}

impl NetworkRuntime {
    /// Instantiate every layer of `net` listed in the manifest.
    pub fn load(
        backend: &dyn InferenceBackend,
        manifest: &Manifest,
        net: Network,
    ) -> Result<NetworkRuntime> {
        let entry: &NetworkEntry = manifest.network(net);
        Self::from_layers(backend, net, manifest.batch, &entry.layers, Some(manifest.dir.as_path()))
    }

    /// Instantiate from raw layer entries — the manifest-free path used
    /// by synthetic-network tests and tools.  `artifact_dir` resolves
    /// each entry's relative artifact paths for backends that compile
    /// from disk; interpreter backends run without it.
    pub fn from_layers(
        backend: &dyn InferenceBackend,
        net: Network,
        batch: usize,
        layers: &[LayerEntry],
        artifact_dir: Option<&Path>,
    ) -> Result<NetworkRuntime> {
        let t0 = Instant::now();
        let mut fp32: Vec<Box<dyn LayerExecutable>> = Vec::with_capacity(layers.len());
        let mut int8: Vec<Option<Box<dyn LayerExecutable>>> = Vec::with_capacity(layers.len());
        for layer in layers {
            let exec = backend
                .load_layer(&LayerSpec {
                    entry: layer,
                    batch,
                    artifact: artifact_dir.map(|d| d.join(&layer.fp32)),
                    quantized: false,
                })
                .with_context(|| format!("{} layer {}", net.name(), layer.index))?;
            fp32.push(exec);
            int8.push(match &layer.int8 {
                Some(rel) => Some(
                    backend
                        .load_layer(&LayerSpec {
                            entry: layer,
                            batch,
                            artifact: artifact_dir.map(|d| d.join(rel)),
                            quantized: true,
                        })
                        .with_context(|| format!("{} int8 layer {}", net.name(), layer.index))?,
                ),
                None => None,
            });
        }
        Ok(NetworkRuntime {
            net,
            batch,
            fp32,
            int8,
            load_ms: t0.elapsed().as_secs_f64() * 1000.0,
        })
    }

    pub fn num_layers(&self) -> usize {
        self.fp32.len()
    }

    fn layer(&self, i: usize, quantized: bool) -> &dyn LayerExecutable {
        if quantized {
            self.int8[i].as_deref().unwrap_or_else(|| &*self.fp32[i])
        } else {
            &*self.fp32[i]
        }
    }

    /// Run layers `[from, to)` sequentially on a flat activation batch.
    /// `quantized` selects the int8 variants (edge-TPU path).
    pub fn run_range(
        &self,
        from: usize,
        to: usize,
        quantized: bool,
        input: &[f32],
    ) -> Result<Vec<f32>> {
        if from > to || to > self.num_layers() {
            bail!("bad layer range {from}..{to} (L = {})", self.num_layers());
        }
        let mut x = input.to_vec();
        for i in from..to {
            x = self
                .layer(i, quantized)
                .run(&x)
                .with_context(|| format!("{} layer {i}", self.net.name()))?;
        }
        Ok(x)
    }

    /// Head segment: layers [0, k), quantized when the TPU path is active.
    pub fn run_head(&self, k: usize, tpu: bool, input: &[f32]) -> Result<Vec<f32>> {
        self.run_range(0, k, tpu, input)
    }

    /// Tail segment: layers [k, L), always fp32 (cloud side).
    pub fn run_tail(&self, k: usize, input: &[f32]) -> Result<Vec<f32>> {
        self.run_range(k, self.num_layers(), false, input)
    }

    /// Full forward with the head quantized up to `quant_upto`.
    pub fn run_full(&self, quant_upto: usize, input: &[f32]) -> Result<Vec<f32>> {
        let head = self.run_range(0, quant_upto, true, input)?;
        self.run_range(quant_upto, self.num_layers(), false, &head)
    }

    /// Argmax class per image of a `[batch, classes]` probability matrix.
    pub fn classify(probs: &[f32], classes: usize) -> Vec<usize> {
        probs
            .chunks_exact(classes)
            .map(|row| {
                row.iter()
                    .enumerate()
                    .max_by(|a, b| a.1.total_cmp(b.1))
                    .map(|(i, _)| i)
                    .unwrap_or(0)
            })
            .collect()
    }
}

/// Tail executor over network runtimes, used by the cloud service loop
/// (`transport::cloud`).  Constructed *inside* the cloud node's thread —
/// layer executables are not `Send` (PJRT handles are thread-local), and
/// the paper's cloud node owns its own runtime anyway (the tail networks
/// are loaded cloud-side, §4.3.2).
pub struct RuntimeTailExecutor {
    pub vgg: NetworkRuntime,
    pub vit: NetworkRuntime,
}

impl RuntimeTailExecutor {
    /// Build the configured backend + both network runtimes (cloud-node
    /// startup).
    pub fn load(manifest: &Manifest) -> Result<RuntimeTailExecutor> {
        let backend = super::backend::default_backend()?;
        Ok(RuntimeTailExecutor {
            vgg: NetworkRuntime::load(backend.as_ref(), manifest, Network::Vgg16)?,
            vit: NetworkRuntime::load(backend.as_ref(), manifest, Network::Vit)?,
        })
    }
}

impl crate::transport::cloud::TailExecutor for RuntimeTailExecutor {
    fn execute_tail(
        &self,
        network: &str,
        split: usize,
        _gpu: bool,
        batch: &[f32],
    ) -> Result<Vec<f32>> {
        let rt = match Network::parse(network)? {
            Network::Vgg16 => &self.vgg,
            Network::Vit => &self.vit,
        };
        rt.run_tail(split, batch)
    }
}

/// Spawn a cloud-node thread: it loads its own runtimes from `manifest`
/// and serves the given endpoint until shutdown.  Returns the join handle
/// carrying the service statistics.
pub fn spawn_cloud_node(
    manifest: Manifest,
    endpoint: crate::transport::channel::Endpoint,
    timeout: std::time::Duration,
) -> std::thread::JoinHandle<Result<crate::transport::cloud::ServeStats>> {
    std::thread::spawn(move || {
        let executor = RuntimeTailExecutor::load(&manifest)?;
        crate::transport::cloud::serve(endpoint, &executor, timeout)
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn classify_argmax() {
        let probs = [0.1, 0.7, 0.2, /*img2*/ 0.5, 0.2, 0.3];
        assert_eq!(NetworkRuntime::classify(&probs, 3), vec![1, 0]);
    }

    #[test]
    fn classify_handles_short_tail() {
        // trailing partial row is ignored by chunks_exact
        let probs = [0.9, 0.1, 0.5];
        assert_eq!(NetworkRuntime::classify(&probs, 2), vec![0]);
    }

    #[test]
    fn classify_survives_nan_rows() {
        // total_cmp ranks NaN above every number, so a NaN poisons only
        // its own row's argmax instead of panicking the whole batch.
        let probs = [0.1, f32::NAN, 0.2, /*img2*/ 0.9, 0.05, 0.05];
        assert_eq!(NetworkRuntime::classify(&probs, 3), vec![1, 0]);
    }
}
