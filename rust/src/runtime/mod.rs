//! Runtime layer: execute the per-layer programs behind a pluggable
//! backend trait.  This is the request-path compute engine.
//!
//! * [`backend`]   — the [`InferenceBackend`] / [`LayerExecutable`]
//!   traits and [`default_backend`] selection (see DESIGN.md §4 for the
//!   backend feature matrix);
//! * [`reference`] — default pure-Rust dense conv/matmul/relu layer
//!   interpreter driven by the manifest shapes: the full head/tail split
//!   path with zero native dependencies;
//! * [`kernels`]   — the interpreter's hot path: im2col packing +
//!   register-tiled GEMM/GEMV with a fixed reduction order (plus the
//!   seed loop nests as the [`kernels::naive`] oracle);
//! * [`arena`]     — ping-pong activation buffers so a whole forward is
//!   O(1) allocations after warmup (see DESIGN.md §10);
//! * [`engine`]    — (`--features xla`) PJRT client + one compiled
//!   executable per HLO-text layer artifact lowered by
//!   `python/compile/aot.py`;
//! * [`network`]   — head/tail pipeline execution over a whole network,
//!   including the int8 (edge-TPU path) variants for VGG16;
//! * [`session`]   — config-keyed cache of resolved execution sessions,
//!   so same-config requests reuse the live session (serving pipeline);
//! * [`evaluate`]  — classify the eval set through the loaded
//!   executables and produce the measured accuracy table (cross-checked
//!   against the python oracle's expectations when the XLA backend runs
//!   the real artifacts).
//!
//! Python is never involved at run time.

pub mod arena;
pub mod backend;
#[cfg(feature = "xla")]
pub mod engine;
pub mod evaluate;
pub mod kernels;
pub mod network;
pub mod reference;
pub mod session;

pub use arena::TensorArena;
pub use backend::{default_backend, InferenceBackend, LayerExecutable, LayerSpec};
#[cfg(feature = "xla")]
pub use engine::{Engine, LayerExec};
pub use network::NetworkRuntime;
pub use reference::ReferenceBackend;
pub use session::{HeadPlan, SessionCache};
