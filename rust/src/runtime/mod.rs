//! PJRT runtime: load AOT HLO artifacts and execute them from rust.
//!
//! This is the request-path compute engine.  `python/compile/aot.py`
//! lowered every layer of both networks to HLO *text*;
//! [`engine::Engine`] compiles each module once on the PJRT CPU client
//! (`xla` crate) and [`network::NetworkRuntime`] composes arbitrary
//! head/tail splits from the per-layer executables.  Python is never
//! involved at run time.
//!
//! * [`engine`]   — PJRT client + one compiled executable per layer;
//! * [`network`]  — head/tail pipeline execution over a whole network,
//!   including the int8 (edge-TPU path) variants for VGG16;
//! * [`evaluate`] — classify the eval set through the real executables
//!   and produce the measured accuracy table (cross-checked against the
//!   python oracle's expectations from the manifest).

pub mod engine;
pub mod evaluate;
pub mod network;

pub use engine::{Engine, LayerExec};
pub use network::NetworkRuntime;
