//! Per-configuration execution sessions for the online path.
//!
//! A serving worker maps each admitted request to a [`crate::space::Config`];
//! before running it must resolve *how* the loaded [`NetworkRuntime`]
//! executes that configuration — which head range and whether the int8
//! (edge-TPU) variants are active — and validate the split against the
//! runtime's layer count.  [`SessionCache`] memoizes that resolution
//! keyed by the full configuration, so consecutive requests mapped to
//! the same `Config` reuse the live session instead of re-deriving and
//! re-validating it, and the hit/miss counters feed the serving report's
//! "reconfigurations avoided" column alongside the apply-state cache
//! ([`crate::serve::cache::ReuseCache`]) and the transport's stream
//! reuse ([`crate::transport::session::StreamSession`]).

use std::collections::HashMap;

use anyhow::{ensure, Result};

use super::network::NetworkRuntime;
use crate::space::{Config, TpuMode};

/// The resolved execution plan for one configuration's edge side.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct HeadPlan {
    /// Layers `[0, split)` run on the edge.
    pub split: usize,
    /// Whether the head runs the int8 (edge-TPU) variants.
    pub quantized: bool,
}

impl HeadPlan {
    pub fn of(config: &Config) -> HeadPlan {
        HeadPlan { split: config.split, quantized: config.tpu != TpuMode::Off }
    }
}

/// Config-keyed cache of resolved sessions with reuse counters.  The
/// configuration space is small (|X| < 1000, the non-dominated set
/// ~12–15 entries, §6.5), so entries are kept for the cache's lifetime.
#[derive(Debug, Default)]
pub struct SessionCache {
    map: HashMap<Config, HeadPlan>,
    pub hits: usize,
    pub misses: usize,
}

impl SessionCache {
    pub fn new() -> SessionCache {
        SessionCache::default()
    }

    /// Resolve (or reuse) the session for `config` against `runtime`.
    pub fn plan(&mut self, runtime: &NetworkRuntime, config: &Config) -> Result<HeadPlan> {
        if let Some(plan) = self.map.get(config) {
            self.hits += 1;
            return Ok(*plan);
        }
        ensure!(
            config.net == runtime.net,
            "config is for {} but the runtime loaded {}",
            config.net.name(),
            runtime.net.name()
        );
        ensure!(
            config.split <= runtime.num_layers(),
            "split {} out of range for {} ({} layers)",
            config.split,
            runtime.net.name(),
            runtime.num_layers()
        );
        let plan = HeadPlan::of(config);
        self.map.insert(*config, plan);
        self.misses += 1;
        Ok(plan)
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::manifest::LayerEntry;
    use crate::runtime::reference::ReferenceBackend;
    use crate::space::Network;

    fn tiny_runtime() -> NetworkRuntime {
        // two dense layers, 4 -> 4 -> 2, enough for plan validation
        let layer = |index: usize, n_in: usize, n_out: usize| LayerEntry {
            index,
            name: format!("l{index}"),
            kind: "dense".into(),
            in_shape: vec![n_in],
            out_shape: vec![n_out],
            out_bytes: (n_out * 4) as u64,
            macs: (n_in * n_out) as u64,
            quantizable: false,
            fp32: format!("l{index}.hlo"),
            int8: None,
        };
        let layers = vec![layer(0, 4, 4), layer(1, 4, 2)];
        NetworkRuntime::from_layers(&ReferenceBackend::new(), Network::Vgg16, 1, &layers, None)
            .expect("reference runtime")
    }

    fn cfg(split: usize, tpu: TpuMode) -> Config {
        Config { net: Network::Vgg16, cpu_idx: 6, tpu, gpu: true, split }
    }

    #[test]
    fn repeat_config_hits_the_cache() {
        let rt = tiny_runtime();
        let mut cache = SessionCache::new();
        let a = cache.plan(&rt, &cfg(1, TpuMode::Max)).unwrap();
        assert_eq!(a, HeadPlan { split: 1, quantized: true });
        assert_eq!((cache.hits, cache.misses), (0, 1));
        let b = cache.plan(&rt, &cfg(1, TpuMode::Max)).unwrap();
        assert_eq!(a, b);
        assert_eq!((cache.hits, cache.misses), (1, 1));
        assert_eq!(cache.len(), 1);
    }

    #[test]
    fn distinct_configs_get_distinct_sessions() {
        let rt = tiny_runtime();
        let mut cache = SessionCache::new();
        cache.plan(&rt, &cfg(1, TpuMode::Max)).unwrap();
        let off = cache.plan(&rt, &cfg(2, TpuMode::Off)).unwrap();
        assert_eq!(off, HeadPlan { split: 2, quantized: false });
        assert_eq!((cache.hits, cache.misses), (0, 2));
        assert_eq!(cache.len(), 2);
    }

    #[test]
    fn out_of_range_split_is_rejected_not_cached() {
        let rt = tiny_runtime();
        let mut cache = SessionCache::new();
        assert!(cache.plan(&rt, &cfg(3, TpuMode::Off)).is_err());
        assert!(cache.is_empty());
    }
}
