//! Measured accuracy table: classify the eval set through the loaded
//! layer executables for every quantization prefix.  Fidelity-grade
//! numbers require the XLA backend (`--features xla`); callers must not
//! persist reference-backend results to the measured cache.
//!
//! accuracy(net, k) with layers < k quantized is computed incrementally:
//! maintain the quantized-prefix activation a_k (a_0 = input, a_{k+1} =
//! int8_layer_k(a_k)) and run the fp32 suffix from each a_k — O(L²/2)
//! layer executions instead of O(L²) naive.  Results are cached to
//! `artifacts/accuracy_rust.json` because the full sweep costs minutes
//! of real PJRT compute.

use std::path::PathBuf;

use anyhow::{Context, Result};

use super::network::NetworkRuntime;
use crate::model::manifest::Manifest;
use crate::simulator::accuracy::AccuracyTable;
use crate::space::Network;
use crate::util::json::Json;

/// Measured accuracies, mirroring the manifest's expected table.
#[derive(Debug, Clone, PartialEq)]
pub struct MeasuredAccuracy {
    pub vgg_fp32: f64,
    pub vgg_int8_prefix: Vec<f64>,
    pub vit_fp32: f64,
}

impl MeasuredAccuracy {
    pub fn to_table(&self) -> AccuracyTable {
        AccuracyTable::from_values(self.vgg_fp32, self.vgg_int8_prefix.clone(), self.vit_fp32)
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("vgg_fp32", Json::num(self.vgg_fp32)),
            (
                "vgg_int8_prefix",
                Json::arr(self.vgg_int8_prefix.iter().map(|&x| Json::num(x))),
            ),
            ("vit_fp32", Json::num(self.vit_fp32)),
        ])
    }

    pub fn from_json(v: &Json) -> Result<MeasuredAccuracy> {
        Ok(MeasuredAccuracy {
            vgg_fp32: v.get("vgg_fp32")?.as_f64()?,
            vgg_int8_prefix: v.get("vgg_int8_prefix")?.as_f64_vec()?,
            vit_fp32: v.get("vit_fp32")?.as_f64()?,
        })
    }
}

fn cache_path(manifest: &Manifest) -> PathBuf {
    manifest.dir.join("accuracy_rust.json")
}

/// Accuracy of predictions vs labels over batched probability outputs.
fn batch_accuracy(hits: usize, total: usize) -> f64 {
    hits as f64 / total.max(1) as f64
}

/// Classify the whole eval set through `run` and count hits.
fn eval_hits<F>(
    images: &[f32],
    labels: &[u8],
    batch: usize,
    img_elems: usize,
    classes: usize,
    mut run: F,
) -> Result<usize>
where
    F: FnMut(&[f32]) -> Result<Vec<f32>>,
{
    let mut hits = 0;
    let n = labels.len();
    assert_eq!(n % batch, 0, "eval count must be a batch multiple");
    for b in 0..(n / batch) {
        let x = &images[b * batch * img_elems..(b + 1) * batch * img_elems];
        let probs = run(x)?;
        let preds = NetworkRuntime::classify(&probs, classes);
        for (i, &p) in preds.iter().enumerate() {
            if p == labels[b * batch + i] as usize {
                hits += 1;
            }
        }
    }
    Ok(hits)
}

/// Compute the full measured-accuracy table (expensive; see cache).
pub fn measure(
    manifest: &Manifest,
    vgg: &NetworkRuntime,
    vit: &NetworkRuntime,
    progress: bool,
) -> Result<MeasuredAccuracy> {
    let (images, labels) = manifest.load_eval_set()?;
    let batch = manifest.batch;
    let img_elems = manifest.img * manifest.img * 3;
    let classes = manifest.classes;
    let n = labels.len();

    // --- ViT fp32 ---
    let vit_hits = eval_hits(&images, &labels, batch, img_elems, classes, |x| {
        vit.run_full(0, x)
    })?;
    if progress {
        println!("[accuracy] vit fp32: {:.4}", batch_accuracy(vit_hits, n));
    }

    // --- VGG int8 prefixes, incremental over k ---
    let l = vgg.num_layers();
    let mut prefix_acc = Vec::with_capacity(l + 1);
    // quantized-prefix activations per batch, advanced one layer per k
    let mut prefix_acts: Vec<Vec<f32>> = (0..n / batch)
        .map(|b| images[b * batch * img_elems..(b + 1) * batch * img_elems].to_vec())
        .collect();
    for k in 0..=l {
        let mut hits = 0;
        for (b, act) in prefix_acts.iter().enumerate() {
            let probs = vgg.run_range(k, l, false, act)?;
            let preds = NetworkRuntime::classify(&probs, classes);
            for (i, &p) in preds.iter().enumerate() {
                if p == labels[b * batch + i] as usize {
                    hits += 1;
                }
            }
        }
        prefix_acc.push(batch_accuracy(hits, n));
        if progress {
            println!("[accuracy] vgg int8 prefix k={k}: {:.4}", prefix_acc[k]);
        }
        if k < l {
            for act in prefix_acts.iter_mut() {
                *act = vgg.run_range(k, k + 1, true, act)?;
            }
        }
    }

    Ok(MeasuredAccuracy {
        vgg_fp32: prefix_acc[0], // k = 0: nothing quantized
        vgg_int8_prefix: prefix_acc,
        vit_fp32: batch_accuracy(vit_hits, n),
    })
}

/// Load the cached table, or measure and cache it.
pub fn measure_cached(
    manifest: &Manifest,
    vgg: &NetworkRuntime,
    vit: &NetworkRuntime,
    progress: bool,
) -> Result<MeasuredAccuracy> {
    let path = cache_path(manifest);
    if path.exists() {
        let v = Json::parse_file(&path)?;
        if let Ok(m) = MeasuredAccuracy::from_json(&v) {
            if m.vgg_int8_prefix.len() == Network::Vgg16.num_layers() + 1 {
                return Ok(m);
            }
        }
        // stale/invalid cache: fall through and re-measure
    }
    let measured = measure(manifest, vgg, vit, progress)?;
    std::fs::write(&path, measured.to_json().encode())
        .with_context(|| format!("writing {}", path.display()))?;
    Ok(measured)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measured_accuracy_json_roundtrip() {
        let m = MeasuredAccuracy {
            vgg_fp32: 0.953,
            vgg_int8_prefix: (0..=22).map(|k| 0.95 - 0.0001 * k as f64).collect(),
            vit_fp32: 0.941,
        };
        let j = m.to_json();
        let back = MeasuredAccuracy::from_json(&Json::parse(&j.encode()).unwrap()).unwrap();
        assert_eq!(back, m);
    }

    #[test]
    fn eval_hits_counts_correctly() {
        // 2 batches of 2 images, 3 "pixels", 2 classes; runner says class
        // = sign of first pixel.
        let images = vec![
            1.0, 0.0, 0.0, /**/ -1.0, 0.0, 0.0, // batch 0
            -1.0, 0.0, 0.0, /**/ 1.0, 0.0, 0.0, // batch 1
        ];
        let labels = vec![0u8, 1, 1, 1];
        let hits = eval_hits(&images, &labels, 2, 3, 2, |x| {
            let mut probs = Vec::new();
            for img in x.chunks_exact(3) {
                if img[0] > 0.0 {
                    probs.extend([0.9, 0.1]);
                } else {
                    probs.extend([0.1, 0.9]);
                }
            }
            Ok(probs)
        })
        .unwrap();
        // predictions: 0, 1, 1, 0 vs labels 0, 1, 1, 1 -> 3 hits
        assert_eq!(hits, 3);
    }
}
