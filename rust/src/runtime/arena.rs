//! Ping-pong activation buffers for zero-alloc forwards.
//!
//! A layer-by-layer forward is a chain `x0 -> x1 -> ... -> xL` where
//! only two activations are ever live: the current layer's input and its
//! output.  [`TensorArena`] owns exactly those two buffers and swaps
//! their roles after every layer, so a whole forward performs **O(1)
//! allocations after warmup** (the first pass grows each buffer to the
//! widest activation it sees; later passes only move lengths within the
//! retained capacity).  Threaded through
//! [`super::network::NetworkRuntime::run_range_in`] and friends; hot
//! callers (the real split executor, the serving batch executor, the
//! forward benches) keep one arena alive across requests.

/// Two reusable activation buffers with a front/back flag.
#[derive(Debug, Default)]
pub struct TensorArena {
    a: Vec<f32>,
    b: Vec<f32>,
    /// When set, `b` is the front (current activation) buffer.
    flip: bool,
}

impl TensorArena {
    pub fn new() -> TensorArena {
        TensorArena::default()
    }

    /// Pre-size both buffers (skips first-pass growth).
    pub fn with_capacity(elems: usize) -> TensorArena {
        TensorArena { a: Vec::with_capacity(elems), b: Vec::with_capacity(elems), flip: false }
    }

    /// Load `input` into the front buffer (copy; reuses capacity).
    pub fn load(&mut self, input: &[f32]) {
        let front = if self.flip { &mut self.b } else { &mut self.a };
        front.clear();
        front.extend_from_slice(input);
    }

    /// Borrow the current activation and the scratch output buffer.
    pub fn pair(&mut self) -> (&[f32], &mut Vec<f32>) {
        if self.flip {
            (self.b.as_slice(), &mut self.a)
        } else {
            (self.a.as_slice(), &mut self.b)
        }
    }

    /// Make the last-written output the new front buffer.
    pub fn swap(&mut self) {
        self.flip = !self.flip;
    }

    /// The current activation (the result, after a forward completes).
    pub fn front(&self) -> &[f32] {
        if self.flip {
            &self.b
        } else {
            &self.a
        }
    }

    /// Consume the arena, moving the current activation out.
    pub fn into_front(self) -> Vec<f32> {
        if self.flip {
            self.b
        } else {
            self.a
        }
    }

    /// Combined capacity of both buffers (warmup telemetry).
    pub fn capacity(&self) -> usize {
        self.a.capacity() + self.b.capacity()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn load_pair_swap_round_trip() {
        let mut arena = TensorArena::new();
        arena.load(&[1.0, 2.0, 3.0]);
        assert_eq!(arena.front(), &[1.0, 2.0, 3.0]);
        {
            let (input, out) = arena.pair();
            assert_eq!(input, &[1.0, 2.0, 3.0]);
            out.clear();
            out.extend(input.iter().map(|v| v * 2.0));
        }
        arena.swap();
        assert_eq!(arena.front(), &[2.0, 4.0, 6.0]);
        assert_eq!(arena.into_front(), vec![2.0, 4.0, 6.0]);
    }

    #[test]
    fn steady_state_does_not_reallocate() {
        let mut arena = TensorArena::with_capacity(64);
        // warmup pass over a 3-layer chain of widths 48 -> 64 -> 16
        let widths = [48usize, 64, 16];
        for _ in 0..2 {
            arena.load(&[1.0; 48]);
            for &wd in &widths {
                let (_, out) = arena.pair();
                out.clear();
                out.resize(wd, 0.5);
                arena.swap();
            }
        }
        let cap = arena.capacity();
        for _ in 0..5 {
            arena.load(&[1.0; 48]);
            for &wd in &widths {
                let (_, out) = arena.pair();
                out.clear();
                out.resize(wd, 0.5);
                arena.swap();
            }
            assert_eq!(arena.capacity(), cap, "steady state must not grow");
        }
        assert_eq!(arena.front().len(), 16);
    }
}
