//! Pluggable inference backends.
//!
//! The runtime layer executes per-layer programs described by the
//! manifest ([`crate::model::manifest::LayerEntry`]); *how* a layer is
//! executed is a backend concern hidden behind [`InferenceBackend`]:
//!
//! * [`crate::runtime::reference::ReferenceBackend`] — default; a pure
//!   Rust dense conv/matmul/relu interpreter with deterministic synthetic
//!   weights.  Zero native dependencies: the full head/tail split path
//!   (edge head → transport → cloud tail) runs anywhere `cargo test`
//!   runs.  Numerically self-consistent, *not* faithful to the trained
//!   models — accuracy-grade experiments need the XLA backend.
//! * `crate::runtime::engine::Engine` (`--features xla`; the module is
//!   compiled out otherwise, so this is deliberately not a doc link) —
//!   the PJRT path: compiles the AOT-lowered HLO text artifacts and
//!   executes the real networks.
//!
//! [`default_backend`] picks one: `DYNASPLIT_BACKEND=reference|xla`
//! overrides, otherwise XLA when compiled in, else the reference
//! interpreter.

use std::path::PathBuf;

use anyhow::Result;

use crate::model::manifest::LayerEntry;

/// Everything a backend needs to instantiate one layer executable.
pub struct LayerSpec<'a> {
    /// Manifest entry: shapes, kind, artifact file names.
    pub entry: &'a LayerEntry,
    /// Lowered batch size; inputs are flat `[batch, *in_shape]`.
    pub batch: usize,
    /// Resolved on-disk artifact (fp32 or int8 per `quantized`), when the
    /// caller has an artifact directory.  Backends that interpret the
    /// manifest directly (reference) ignore it; artifact-compiling
    /// backends (XLA) require it.
    pub artifact: Option<PathBuf>,
    /// Select the int8 (edge-TPU path) variant.  Callers only pass `true`
    /// for layers the manifest marks quantizable.
    pub quantized: bool,
}

/// One instantiated (compiled or interpreted) layer.
///
/// Deliberately not `Send`: the PJRT implementation holds thread-local
/// handles, so each node thread builds its own executables — which is
/// also the honest topology (the paper's cloud node owns its runtime).
pub trait LayerExecutable {
    /// Execute the layer on a flat `[batch, *in_shape]` activation.
    /// Interpreter backends additionally accept any positive multiple of
    /// one image's elements (variable batch — how the serving pipeline
    /// runs a coalesced batch through one head call); compiled backends
    /// may require exactly `in_elems()`.
    fn run(&self, input: &[f32]) -> Result<Vec<f32>>;

    /// Execute into a caller-owned buffer: `out` is cleared and resized
    /// to the output element count, reusing its capacity — the seam the
    /// zero-alloc forward path ([`crate::runtime::TensorArena`]) builds
    /// on.  The default shim delegates to [`LayerExecutable::run`];
    /// backends with allocation-free interpreters override it.
    fn run_into(&self, input: &[f32], out: &mut Vec<f32>) -> Result<()> {
        *out = self.run(input)?;
        Ok(())
    }

    /// Lowered batch size.
    fn batch(&self) -> usize;

    /// Input elements of a full batch.
    fn in_elems(&self) -> usize;

    /// Output elements of a full batch.
    fn out_elems(&self) -> usize;

    /// Time spent compiling/instantiating this layer (ms), reported by
    /// `dynasplit runtime-info`.
    fn compile_ms(&self) -> f64;
}

/// A source of layer executables.
pub trait InferenceBackend {
    /// Stable identifier: `"reference"` or `"xla"`.  Tests and the CLI
    /// use it to tell fidelity-grade backends from self-consistent ones.
    fn name(&self) -> &'static str;

    /// Human-readable platform string (PJRT platform name, etc.).
    fn platform(&self) -> String;

    /// Instantiate one layer.
    fn load_layer(&self, spec: &LayerSpec) -> Result<Box<dyn LayerExecutable>>;
}

/// Construct the configured backend.
///
/// `DYNASPLIT_BACKEND=reference` forces the interpreter even in XLA
/// builds (useful to exercise the portable path); `DYNASPLIT_BACKEND=xla`
/// errors unless compiled with `--features xla`.
pub fn default_backend() -> Result<Box<dyn InferenceBackend>> {
    let choice = std::env::var("DYNASPLIT_BACKEND").unwrap_or_default();
    match choice.as_str() {
        "" | "auto" => auto_backend(),
        "reference" => Ok(Box::new(super::reference::ReferenceBackend::from_env())),
        #[cfg(feature = "xla")]
        "xla" => Ok(Box::new(super::engine::Engine::cpu()?)),
        other => anyhow::bail!(
            "unknown DYNASPLIT_BACKEND {other:?} (expected auto|reference{})",
            if cfg!(feature = "xla") { "|xla" } else { "; rebuild with --features xla for xla" }
        ),
    }
}

fn auto_backend() -> Result<Box<dyn InferenceBackend>> {
    #[cfg(feature = "xla")]
    return Ok(Box::new(super::engine::Engine::cpu()?));
    #[cfg(not(feature = "xla"))]
    Ok(Box::new(super::reference::ReferenceBackend::from_env()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_backend_resolves_without_native_deps() {
        // Under default features this must always succeed (reference
        // interpreter); under --features xla it may fail against the
        // vendored stub, which is also a valid outcome to exercise.
        match default_backend() {
            Ok(b) => {
                assert!(!b.name().is_empty());
                assert!(!b.platform().is_empty());
            }
            Err(_) => assert!(cfg!(feature = "xla"), "reference backend must not fail"),
        }
    }

    #[test]
    fn reference_backend_loads_layers_without_artifacts() {
        let entry = LayerEntry {
            index: 0,
            name: "l0".into(),
            kind: "conv".into(),
            in_shape: vec![4],
            out_shape: vec![4],
            out_bytes: 16,
            macs: 100,
            quantizable: false,
            fp32: "x.hlo.txt".into(),
            int8: None,
        };
        // reference backend loads a layer without any artifact on disk
        let b = super::super::reference::ReferenceBackend::new();
        let spec = LayerSpec { entry: &entry, batch: 2, artifact: None, quantized: false };
        assert!(b.load_layer(&spec).is_ok());
    }
}
