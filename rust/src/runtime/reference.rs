//! Pure-Rust reference backend: a dense f32 conv/matmul/relu layer
//! interpreter driven by the same manifest shapes as the XLA engine.
//!
//! Weights are *synthetic*: generated deterministically per layer from a
//! seed derived from the layer's name and index (quantized variants snap
//! the same weights to an int8 grid, mimicking post-training
//! quantization's small perturbation).  That makes the backend
//! numerically self-consistent — head/tail compositions reproduce the
//! full forward bit-for-bit, int8 prefixes stay close to fp32 — while
//! requiring zero artifacts and zero native libraries, so the complete
//! split-execution path (edge head → transport → cloud tail) is
//! exercisable by `cargo test` in any environment.
//!
//! Fidelity to the *trained* models (real accuracies) is exclusively the
//! XLA backend's job (`--features xla`).
//!
//! Op selection per layer, from the manifest shapes alone:
//!
//! * 3-D in / 3-D out (`[H, W, C]` activations) → 3×3 same-padded
//!   convolution, stride inferred from the spatial ratio, ReLU;
//! * small dense shapes → full matmul + bias + ReLU;
//! * anything else (large flattens, attention blocks) → a strided
//!   sparse mixing matmul (fixed taps per output), so cost stays linear
//!   in the output size instead of `O(in × out)`.
//!
//! **Hot path**: convs run im2col + the register-tiled GEMM and dense
//! layers the unrolled GEMV from [`super::kernels`]; the seed
//! interpreter's loop nests survive as [`super::kernels::naive`] and are
//! selected by [`ReferenceBackend::naive_oracle`] for property tests and
//! the `*_naive` bench baselines.  Both paths are deterministic
//! run-to-run and across thread counts; they differ from *each other*
//! only by f32 summation order (≤ 1e-4 relative, property-tested).
//!
//! Unlike compiled backends, the interpreter accepts any positive
//! multiple of one image's elements — the serving pipeline exploits this
//! to run a coalesced batch through one head call.

use std::cell::RefCell;

use anyhow::{bail, Result};

use super::backend::{InferenceBackend, LayerExecutable, LayerSpec};
use super::kernels;
use crate::util::rng::Pcg32;

/// Dense-ops-per-output cap above which the interpreter switches from a
/// full matmul to the strided mixer (keeps debug-build tests fast).
const DENSE_WEIGHT_CAP: usize = 1 << 22;

/// Taps per output element in the strided mixer.
const MIX_TAPS: usize = 16;

/// The default, dependency-free backend.
#[derive(Debug, Clone, Copy)]
pub struct ReferenceBackend {
    /// Worker threads for the data-parallel kernels (GEMM output rows,
    /// batch images).  `1` = fully sequential; results are bit-identical
    /// for every value.
    pub threads: usize,
    /// Run the seed interpreter loops instead of the im2col/GEMM path
    /// (the correctness oracle).
    pub naive: bool,
}

impl Default for ReferenceBackend {
    fn default() -> Self {
        ReferenceBackend { threads: 1, naive: false }
    }
}

impl ReferenceBackend {
    pub fn new() -> ReferenceBackend {
        ReferenceBackend::default()
    }

    /// Fast path with up to `threads` kernel threads.
    pub fn with_threads(threads: usize) -> ReferenceBackend {
        ReferenceBackend { threads: threads.max(1), naive: false }
    }

    /// The seed interpreter loops — the oracle the fast path is
    /// property-tested against.
    pub fn naive_oracle() -> ReferenceBackend {
        ReferenceBackend { threads: 1, naive: true }
    }

    /// Default construction honoring the `DYNASPLIT_THREADS` knob.
    pub fn from_env() -> ReferenceBackend {
        let threads = std::env::var("DYNASPLIT_THREADS")
            .ok()
            .and_then(|s| s.parse::<usize>().ok())
            .unwrap_or(1);
        ReferenceBackend::with_threads(threads)
    }
}

impl InferenceBackend for ReferenceBackend {
    fn name(&self) -> &'static str {
        "reference"
    }

    fn platform(&self) -> String {
        format!(
            "reference-cpu (synthetic weights, {} kernel, {} thread{})",
            if self.naive { "naive" } else { "im2col+gemm" },
            self.threads,
            if self.threads == 1 { "" } else { "s" }
        )
    }

    fn load_layer(&self, spec: &LayerSpec) -> Result<Box<dyn LayerExecutable>> {
        let sw = crate::serve::clock::Stopwatch::start();
        let op = RefOp::build(spec)?;
        Ok(Box::new(RefLayer {
            batch: spec.batch,
            in_per_img: spec.entry.in_shape.iter().product(),
            out_per_img: spec.entry.out_shape.iter().product(),
            op,
            threads: self.threads.max(1),
            naive: self.naive,
            // one scratch per kernel thread, built here so the hot
            // `run_into` path never allocates the pool itself
            scratch: RefCell::new((0..self.threads.max(1)).map(|_| Vec::new()).collect()),
            build_ms: sw.elapsed_ms(),
        }))
    }
}

/// One interpreted layer.
struct RefLayer {
    batch: usize,
    in_per_img: usize,
    out_per_img: usize,
    op: RefOp,
    threads: usize,
    naive: bool,
    /// Reusable im2col patch buffers, one per kernel thread (interior
    /// mutability: `LayerExecutable` is `&self` and deliberately not
    /// `Send`, so a `RefCell` is sound and keeps forwards zero-alloc
    /// after warmup).
    scratch: RefCell<Vec<Vec<f32>>>,
    build_ms: f64,
}

enum RefOp {
    /// 3×3 same-padded convolution over `[H, W, C]`, strided, ReLU.
    Conv {
        h_in: usize,
        w_in: usize,
        c_in: usize,
        h_out: usize,
        w_out: usize,
        c_out: usize,
        stride: usize,
        /// `[c_out, 3, 3, c_in]` row-major.
        w: Vec<f32>,
        b: Vec<f32>,
    },
    /// Full matmul `[n_out, n_in]` + bias, ReLU.
    Dense { n_in: usize, n_out: usize, w: Vec<f32>, b: Vec<f32> },
    /// Strided sparse mixer: each output reads [`MIX_TAPS`] inputs.
    Mix { n_in: usize, n_out: usize, w: Vec<f32>, b: Vec<f32> },
}

/// Deterministic per-layer weight seed: stable across edge and cloud
/// nodes so separately-constructed runtimes agree bit-for-bit.
fn layer_seed(spec: &LayerSpec) -> u64 {
    crate::util::hash::fnv1a(spec.entry.name.bytes().map(u64::from))
        ^ (spec.entry.index as u64).wrapping_mul(0x9e37_79b9_7f4a_7c15)
}

/// Uniform weights scaled He-style for variance preservation under ReLU;
/// the quantized variant snaps the *same* weights to a 127-step grid.
fn gen_weights(rng: &mut Pcg32, n: usize, fan_in: usize, quantized: bool) -> Vec<f32> {
    let s = (6.0 / fan_in.max(1) as f64).sqrt();
    let mut w: Vec<f32> = (0..n).map(|_| rng.uniform(-s, s) as f32).collect();
    if quantized {
        let m = w.iter().fold(0.0f32, |a, &x| a.max(x.abs()));
        if m > 0.0 {
            let delta = m / 127.0;
            for x in w.iter_mut() {
                *x = (*x / delta).round() * delta;
            }
        }
    }
    w
}

fn gen_bias(rng: &mut Pcg32, n: usize) -> Vec<f32> {
    (0..n).map(|_| rng.uniform(0.0, 0.05) as f32).collect()
}

impl RefOp {
    fn build(spec: &LayerSpec) -> Result<RefOp> {
        let in_shape = &spec.entry.in_shape;
        let out_shape = &spec.entry.out_shape;
        let n_in: usize = in_shape.iter().product();
        let n_out: usize = out_shape.iter().product();
        if n_in == 0 || n_out == 0 {
            bail!(
                "layer {} has empty shape: in {:?} out {:?}",
                spec.entry.index,
                in_shape,
                out_shape
            );
        }
        // Weight generation ignores `quantized` for the *values drawn* (the
        // int8 variant must share the fp32 weights) — quantization is a
        // post-pass inside gen_weights.
        let mut rng = Pcg32::new(layer_seed(spec), 0x5eed);
        Ok(if in_shape.len() == 3 && out_shape.len() == 3 {
            let (h_in, w_in, c_in) = (in_shape[0], in_shape[1], in_shape[2]);
            let (h_out, w_out, c_out) = (out_shape[0], out_shape[1], out_shape[2]);
            let stride = (h_in / h_out.max(1)).max(1);
            let fan_in = 9 * c_in;
            let w = gen_weights(&mut rng, c_out * fan_in, fan_in, spec.quantized);
            let b = gen_bias(&mut rng, c_out);
            RefOp::Conv { h_in, w_in, c_in, h_out, w_out, c_out, stride, w, b }
        } else if n_in * n_out <= DENSE_WEIGHT_CAP {
            let w = gen_weights(&mut rng, n_out * n_in, n_in, spec.quantized);
            let b = gen_bias(&mut rng, n_out);
            RefOp::Dense { n_in, n_out, w, b }
        } else {
            let w = gen_weights(&mut rng, n_out * MIX_TAPS, MIX_TAPS, spec.quantized);
            let b = gen_bias(&mut rng, n_out);
            RefOp::Mix { n_in, n_out, w, b }
        })
    }

    /// Seed interpreter loops over one image (the correctness oracle).
    fn forward_naive(&self, x: &[f32], out: &mut [f32]) {
        match self {
            RefOp::Conv { h_in, w_in, c_in, h_out, w_out, c_out, stride, w, b } => {
                kernels::naive::conv3x3(
                    x, w, b, *h_in, *w_in, *c_in, *h_out, *w_out, *c_out, *stride, out,
                );
            }
            RefOp::Dense { n_in, n_out, w, b } => {
                kernels::naive::dense(x, w, b, *n_in, *n_out, out);
            }
            RefOp::Mix { .. } => self.forward_mix(x, out),
        }
    }

    /// Fast kernels over one image.  `patches` is the reusable im2col
    /// scratch; `threads` parallelizes GEMM output rows.
    fn forward_fast(&self, x: &[f32], out: &mut [f32], patches: &mut Vec<f32>, threads: usize) {
        match self {
            RefOp::Conv { h_in, w_in, c_in, h_out, w_out, c_out, stride, w, b } => {
                kernels::im2col_3x3(x, *h_in, *w_in, *c_in, *h_out, *w_out, *stride, patches);
                kernels::gemm_bias_relu(
                    patches,
                    w,
                    b,
                    h_out * w_out,
                    *c_out,
                    9 * c_in,
                    out,
                    threads,
                );
            }
            RefOp::Dense { n_in, n_out, w, b } => {
                kernels::gemv_bias_relu(w, x, b, *n_out, *n_in, out, threads);
            }
            // the mixer is memory-bound (16 gathered taps per output):
            // the loop *is* the fast path
            RefOp::Mix { .. } => self.forward_mix(x, out),
        }
    }

    fn forward_mix(&self, x: &[f32], out: &mut [f32]) {
        let RefOp::Mix { n_in, n_out, w, b } = self else {
            unreachable!("forward_mix on non-mixer op");
        };
        for (j, o) in out.iter_mut().enumerate().take(*n_out) {
            let mut acc = b[j];
            for t in 0..MIX_TAPS {
                let idx = (j.wrapping_mul(31) + t.wrapping_mul(17)) % n_in;
                acc += w[j * MIX_TAPS + t] * x[idx];
            }
            *o = acc.max(0.0);
        }
    }
}

impl RefLayer {
    /// Number of images in `input`; the interpreter accepts any positive
    /// multiple of one image's elements (variable batch), with the
    /// lowered `batch` as the nominal size.
    fn images(&self, input: &[f32]) -> Result<usize> {
        if input.is_empty() || input.len() % self.in_per_img != 0 {
            bail!(
                "layer expects {} input elements (batch {} x {}) or another positive \
                 multiple of {}, got {}",
                self.in_elems(),
                self.batch,
                self.in_per_img,
                self.in_per_img,
                input.len()
            );
        }
        Ok(input.len() / self.in_per_img)
    }
}

impl LayerExecutable for RefLayer {
    fn run(&self, input: &[f32]) -> Result<Vec<f32>> {
        let mut out = Vec::new();
        self.run_into(input, &mut out)?;
        Ok(out)
    }

    fn run_into(&self, input: &[f32], out: &mut Vec<f32>) -> Result<()> {
        let images = self.images(input)?;
        out.clear();
        out.resize(images * self.out_per_img, 0.0);
        if self.naive {
            for (img_in, img_out) in input
                .chunks_exact(self.in_per_img)
                .zip(out.chunks_exact_mut(self.out_per_img))
            {
                self.op.forward_naive(img_in, img_out);
            }
            return Ok(());
        }
        let mut pool = self.scratch.borrow_mut();
        debug_assert!(pool.len() >= self.threads.max(1), "scratch pool sized at load");
        if self.threads > 1 && images > 1 {
            // data-parallel over batch images, one scratch per thread;
            // per-image reduction order is unchanged, so results are
            // bit-identical to the sequential path
            let (in_per, out_per) = (self.in_per_img, self.out_per_img);
            let op = &self.op;
            crate::util::parallel::par_rows(
                self.threads,
                out,
                images,
                out_per,
                pool.as_mut_slice(),
                |img0, chunk, patches| {
                    for (i, img_out) in chunk.chunks_exact_mut(out_per).enumerate() {
                        let img_in = &input[(img0 + i) * in_per..(img0 + i + 1) * in_per];
                        op.forward_fast(img_in, img_out, patches, 1);
                    }
                },
            );
        } else {
            let patches = &mut pool[0];
            for (img_in, img_out) in input
                .chunks_exact(self.in_per_img)
                .zip(out.chunks_exact_mut(self.out_per_img))
            {
                self.op.forward_fast(img_in, img_out, patches, self.threads);
            }
        }
        Ok(())
    }

    fn batch(&self) -> usize {
        self.batch
    }

    fn in_elems(&self) -> usize {
        self.batch * self.in_per_img
    }

    fn out_elems(&self) -> usize {
        self.batch * self.out_per_img
    }

    fn compile_ms(&self) -> f64 {
        self.build_ms
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::manifest::LayerEntry;

    fn entry(
        index: usize,
        kind: &str,
        in_shape: Vec<usize>,
        out_shape: Vec<usize>,
        int8: bool,
    ) -> LayerEntry {
        LayerEntry {
            index,
            name: format!("{kind}_{index:02}"),
            kind: kind.to_string(),
            in_shape,
            out_shape,
            out_bytes: 0,
            macs: 0,
            quantizable: int8,
            fp32: format!("fp32/layer_{index:02}.hlo.txt"),
            int8: int8.then(|| format!("int8/layer_{index:02}.hlo.txt")),
        }
    }

    fn load_with(
        backend: ReferenceBackend,
        entry: &LayerEntry,
        batch: usize,
        quantized: bool,
    ) -> Box<dyn LayerExecutable> {
        backend
            .load_layer(&LayerSpec { entry, batch, artifact: None, quantized })
            .unwrap()
    }

    fn load(entry: &LayerEntry, batch: usize, quantized: bool) -> Box<dyn LayerExecutable> {
        load_with(ReferenceBackend::new(), entry, batch, quantized)
    }

    fn ramp(n: usize) -> Vec<f32> {
        (0..n).map(|i| (i as f32 * 0.37).sin()).collect()
    }

    #[test]
    fn conv_layer_shapes_and_relu() {
        let e = entry(0, "conv", vec![6, 6, 2], vec![6, 6, 4], false);
        let layer = load(&e, 2, false);
        assert_eq!(layer.batch(), 2);
        assert_eq!(layer.in_elems(), 2 * 72);
        assert_eq!(layer.out_elems(), 2 * 144);
        let out = layer.run(&ramp(layer.in_elems())).unwrap();
        assert_eq!(out.len(), layer.out_elems());
        assert!(out.iter().all(|&v| v >= 0.0 && v.is_finite()), "ReLU output");
        assert!(out.iter().any(|&v| v > 0.0), "not all dead");
    }

    #[test]
    fn strided_conv_downsamples() {
        let e = entry(1, "conv", vec![8, 8, 3], vec![4, 4, 5], false);
        let layer = load(&e, 1, false);
        let out = layer.run(&ramp(8 * 8 * 3)).unwrap();
        assert_eq!(out.len(), 4 * 4 * 5);
    }

    #[test]
    fn dense_layer_small_shapes() {
        let e = entry(2, "fc", vec![36], vec![10], false);
        let layer = load(&e, 3, false);
        let out = layer.run(&ramp(3 * 36)).unwrap();
        assert_eq!(out.len(), 30);
        assert!(out.iter().all(|&v| v >= 0.0 && v.is_finite()));
    }

    #[test]
    fn large_shapes_take_the_mixer_path() {
        // 4096 x 4096 > DENSE_WEIGHT_CAP: must not allocate a 16M-element
        // weight matrix, and must still execute quickly.
        let e = entry(3, "block", vec![4096], vec![4096], false);
        let layer = load(&e, 1, false);
        let out = layer.run(&ramp(4096)).unwrap();
        assert_eq!(out.len(), 4096);
        assert!(out.iter().all(|&v| v.is_finite()));
    }

    #[test]
    fn deterministic_across_instances() {
        let e = entry(4, "conv", vec![5, 5, 3], vec![5, 5, 4], false);
        let a = load(&e, 2, false);
        let b = load(&e, 2, false);
        let x = ramp(a.in_elems());
        assert_eq!(a.run(&x).unwrap(), b.run(&x).unwrap());
    }

    #[test]
    fn different_layers_differ() {
        let e0 = entry(5, "fc", vec![20], vec![20], false);
        let e1 = entry(6, "fc", vec![20], vec![20], false);
        let x = ramp(20);
        assert_ne!(load(&e0, 1, false).run(&x).unwrap(), load(&e1, 1, false).run(&x).unwrap());
    }

    #[test]
    fn quantized_variant_close_but_not_identical() {
        let e = entry(7, "conv", vec![6, 6, 3], vec![6, 6, 4], true);
        let fp = load(&e, 1, false);
        let q = load(&e, 1, true);
        let x = ramp(fp.in_elems());
        let a = fp.run(&x).unwrap();
        let b = q.run(&x).unwrap();
        assert_ne!(a, b, "int8 grid must perturb the weights");
        let scale = a.iter().fold(0.0f32, |m, &v| m.max(v.abs())).max(1e-6);
        let max_d = a.iter().zip(&b).map(|(p, q)| (p - q).abs()).fold(0.0f32, f32::max);
        assert!(max_d / scale < 0.1, "int8 diverged: {max_d} vs scale {scale}");
    }

    #[test]
    fn wrong_input_length_rejected() {
        let e = entry(8, "fc", vec![10], vec![10], false);
        let layer = load(&e, 1, false);
        let err = layer.run(&[1.0; 9]).unwrap_err();
        assert!(format!("{err:#}").contains("expects 10"));
    }

    #[test]
    fn variable_batch_is_a_multiple_of_one_image() {
        // lowered at batch 2, but 3 images (a coalesced serve batch) run
        // fine; 0 images and non-multiples stay rejected
        let e = entry(9, "conv", vec![4, 4, 2], vec![4, 4, 3], false);
        let layer = load(&e, 2, false);
        let three = layer.run(&ramp(3 * 32)).unwrap();
        assert_eq!(three.len(), 3 * 48);
        let one = layer.run(&ramp(32)).unwrap();
        assert_eq!(one, three[..48], "batched image 0 == solo image 0");
        assert!(layer.run(&[]).is_err(), "empty input rejected");
        assert!(layer.run(&ramp(33)).is_err(), "non-multiple rejected");
    }

    #[test]
    fn empty_shape_rejected() {
        let e = entry(10, "fc", vec![0], vec![10], false);
        let r = ReferenceBackend::new().load_layer(&LayerSpec {
            entry: &e,
            batch: 1,
            artifact: None,
            quantized: false,
        });
        assert!(r.is_err());
    }

    #[test]
    fn fast_path_matches_naive_oracle_closely() {
        for (i, e) in [
            entry(11, "conv", vec![7, 9, 4], vec![7, 9, 6], false),
            entry(12, "conv", vec![8, 8, 5], vec![4, 4, 7], false),
            entry(13, "fc", vec![50], vec![33], false),
        ]
        .iter()
        .enumerate()
        {
            let fast = load_with(ReferenceBackend::new(), e, 2, false);
            let naive = load_with(ReferenceBackend::naive_oracle(), e, 2, false);
            let x = ramp(fast.in_elems());
            let a = fast.run(&x).unwrap();
            let b = naive.run(&x).unwrap();
            let scale = b.iter().fold(1.0f32, |m, &v| m.max(v.abs()));
            let max_d = a.iter().zip(&b).map(|(p, q)| (p - q).abs()).fold(0.0f32, f32::max);
            assert!(max_d <= 1e-4 * scale, "case {i}: {max_d} vs scale {scale}");
        }
    }

    #[test]
    fn thread_counts_are_bit_identical() {
        // 4 x 24x24x8 = 18432 output elements: above the parallel
        // executor's inline threshold, so threads really spawn
        let e = entry(14, "conv", vec![24, 24, 8], vec![24, 24, 8], false);
        let x = ramp(4 * 24 * 24 * 8);
        let one = load_with(ReferenceBackend::with_threads(1), &e, 4, false).run(&x).unwrap();
        let three = load_with(ReferenceBackend::with_threads(3), &e, 4, false).run(&x).unwrap();
        assert_eq!(one, three, "thread count must not change results");
    }

    #[test]
    fn run_into_matches_run_and_reuses_the_buffer() {
        let e = entry(15, "conv", vec![6, 6, 3], vec![6, 6, 5], false);
        let layer = load(&e, 2, false);
        let x = ramp(layer.in_elems());
        let want = layer.run(&x).unwrap();
        let mut out = Vec::new();
        layer.run_into(&x, &mut out).unwrap();
        assert_eq!(out, want);
        let ptr = out.as_ptr();
        let cap = out.capacity();
        layer.run_into(&x, &mut out).unwrap();
        assert_eq!(out, want);
        assert_eq!((out.as_ptr(), out.capacity()), (ptr, cap), "steady state must not realloc");
    }

    #[test]
    fn backend_identity() {
        let b = ReferenceBackend::new();
        assert_eq!(b.name(), "reference");
        assert!(b.platform().contains("reference"));
        assert!(ReferenceBackend::naive_oracle().platform().contains("naive"));
        assert_eq!(ReferenceBackend::from_env().threads.max(1), ReferenceBackend::from_env().threads);
    }
}
