//! Fast f32 kernels for the reference backend's hot path: im2col
//! packing + cache-blocked, register-tiled GEMM, and an unrolled GEMV.
//!
//! Layout contract (shared with [`super::reference`]):
//!
//! * conv weights are `[c_out, 3, 3, c_in]` row-major, i.e. each output
//!   channel is one contiguous row of `K = 9 * c_in` reduction elements;
//! * [`im2col_3x3`] packs the input `[H, W, C]` image into a patch
//!   matrix of `M = h_out * w_out` rows with the **same** `[ky][kx][ci]`
//!   reduction order, so the convolution is exactly `patches · weightsᵀ`;
//! * [`gemm_bias_relu`] computes `C[M, N] = relu(A[M, K] · B[N, K]ᵀ + b)`
//!   with `MR x NR` register tiles and the K reduction always walked
//!   sequentially `0..K` into a single accumulator per output element —
//!   a **fixed reduction order**, so results are bit-identical from run
//!   to run and for every thread count (rows are partitioned, never
//!   split).  The order *differs* from the naive loop's (ky/kx/ci window
//!   walk skips padding), hence the property-test contract is
//!   approximate equality (≤ 1e-4 relative) against the [`naive`]
//!   oracle, plus exact determinism of the fast path itself.
//!
//! Mirrors the accelerator-kernel discipline (blocked grids over the
//! output, packed operands, scratch reuse) at CPU register scale.

use crate::util::parallel::par_rows;

/// Register tile height (rows of C per micro-kernel call).
pub const MR: usize = 4;
/// Register tile width (columns of C per micro-kernel call).
pub const NR: usize = 4;

/// Pack 3×3 same-padded strided patches of `x` (`[h_in, w_in, c_in]`
/// row-major) into `patches`: `M = h_out * w_out` rows of `K = 9 * c_in`
/// elements in `[ky][kx][ci]` order, zero-filled where the window hangs
/// over the border.  `patches` is resized (reused capacity: zero-alloc
/// after warmup).
#[allow(clippy::too_many_arguments)]
pub fn im2col_3x3(
    x: &[f32],
    h_in: usize,
    w_in: usize,
    c_in: usize,
    h_out: usize,
    w_out: usize,
    stride: usize,
    patches: &mut Vec<f32>,
) {
    debug_assert_eq!(x.len(), h_in * w_in * c_in);
    let k = 9 * c_in;
    patches.clear();
    patches.resize(h_out * w_out * k, 0.0);
    for oy in 0..h_out {
        for ox in 0..w_out {
            let row = &mut patches[(oy * w_out + ox) * k..(oy * w_out + ox + 1) * k];
            for ky in 0..3usize {
                let iy = (oy * stride + ky) as isize - 1;
                if iy < 0 || iy >= h_in as isize {
                    // stays zero (padding)
                    continue;
                }
                for kx in 0..3usize {
                    let ix = (ox * stride + kx) as isize - 1;
                    if ix < 0 || ix >= w_in as isize {
                        continue;
                    }
                    let src = (iy as usize * w_in + ix as usize) * c_in;
                    let dst = (ky * 3 + kx) * c_in;
                    row[dst..dst + c_in].copy_from_slice(&x[src..src + c_in]);
                }
            }
        }
    }
}

/// `out[M, N] = relu(A[M, K] · B[N, K]ᵀ + bias[N])`, row-major
/// everywhere.  Rows of `out` are partitioned across up to `threads`
/// scoped threads; within a row the K reduction is strictly sequential,
/// so the result is independent of `threads`.
#[allow(clippy::too_many_arguments)]
pub fn gemm_bias_relu(
    a: &[f32],
    b: &[f32],
    bias: &[f32],
    m: usize,
    n: usize,
    k: usize,
    out: &mut [f32],
    threads: usize,
) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), n * k);
    debug_assert_eq!(bias.len(), n);
    debug_assert_eq!(out.len(), m * n);
    let mut ctx = vec![(); threads.max(1)];
    par_rows(threads, out, m, n, &mut ctx, |row0, chunk, _| {
        gemm_block(a, b, bias, row0, chunk.len() / n.max(1), n, k, chunk);
    });
}

/// One thread's contiguous row block: `rows` rows of C starting at
/// absolute row `row0`, tiled MR x NR.
#[allow(clippy::too_many_arguments)]
fn gemm_block(
    a: &[f32],
    b: &[f32],
    bias: &[f32],
    row0: usize,
    rows: usize,
    n: usize,
    k: usize,
    c: &mut [f32],
) {
    let mut i = 0;
    while i < rows {
        let mr = MR.min(rows - i);
        let mut j = 0;
        while j < n {
            let nr = NR.min(n - j);
            if mr == MR && nr == NR {
                micro_4x4(a, b, bias, row0 + i, i, j, n, k, c);
            } else {
                micro_edge(a, b, bias, row0 + i, i, j, mr, nr, n, k, c);
            }
            j += nr;
        }
        i += mr;
    }
}

/// Full MR x NR = 4x4 register tile: 16 accumulators live across the
/// whole K walk, 8 loads feed 16 FMAs per step.
#[allow(clippy::too_many_arguments)]
#[inline]
fn micro_4x4(
    a: &[f32],
    b: &[f32],
    bias: &[f32],
    ai: usize,
    ci: usize,
    j: usize,
    n: usize,
    k: usize,
    c: &mut [f32],
) {
    let a0 = &a[ai * k..(ai + 1) * k];
    let a1 = &a[(ai + 1) * k..(ai + 2) * k];
    let a2 = &a[(ai + 2) * k..(ai + 3) * k];
    let a3 = &a[(ai + 3) * k..(ai + 4) * k];
    let b0 = &b[j * k..(j + 1) * k];
    let b1 = &b[(j + 1) * k..(j + 2) * k];
    let b2 = &b[(j + 2) * k..(j + 3) * k];
    let b3 = &b[(j + 3) * k..(j + 4) * k];
    let mut acc = [[0.0f32; NR]; MR];
    for kk in 0..k {
        let av = [a0[kk], a1[kk], a2[kk], a3[kk]];
        let bv = [b0[kk], b1[kk], b2[kk], b3[kk]];
        for (accr, &ar) in acc.iter_mut().zip(&av) {
            for (accs, &bs) in accr.iter_mut().zip(&bv) {
                *accs += ar * bs;
            }
        }
    }
    for (r, accr) in acc.iter().enumerate() {
        let row = &mut c[(ci + r) * n + j..(ci + r) * n + j + NR];
        for (s, (dst, &v)) in row.iter_mut().zip(accr).enumerate() {
            *dst = (v + bias[j + s]).max(0.0);
        }
    }
}

/// Edge tile (m or n remainder): same fixed K order, scalar accumulators.
#[allow(clippy::too_many_arguments)]
#[inline]
fn micro_edge(
    a: &[f32],
    b: &[f32],
    bias: &[f32],
    ai: usize,
    ci: usize,
    j: usize,
    mr: usize,
    nr: usize,
    n: usize,
    k: usize,
    c: &mut [f32],
) {
    for r in 0..mr {
        let ar = &a[(ai + r) * k..(ai + r + 1) * k];
        for s in 0..nr {
            let br = &b[(j + s) * k..(j + s + 1) * k];
            let mut acc = 0.0f32;
            for (x, y) in ar.iter().zip(br) {
                acc += x * y;
            }
            c[(ci + r) * n + j + s] = (acc + bias[j + s]).max(0.0);
        }
    }
}

/// `out[N] = relu(W[N, K] · x[K] + bias[N])` — the dense per-image path.
/// Four partial accumulators (k ≡ 0..3 mod 4) combined in a fixed order:
/// deterministic per run and thread count, ~4x the ILP of a serial dot.
#[allow(clippy::too_many_arguments)]
pub fn gemv_bias_relu(
    w: &[f32],
    x: &[f32],
    bias: &[f32],
    n: usize,
    k: usize,
    out: &mut [f32],
    threads: usize,
) {
    debug_assert_eq!(w.len(), n * k);
    debug_assert_eq!(x.len(), k);
    debug_assert_eq!(bias.len(), n);
    debug_assert_eq!(out.len(), n);
    let mut ctx = vec![(); threads.max(1)];
    par_rows(threads, out, n, 1, &mut ctx, |row0, chunk, _| {
        for (i, o) in chunk.iter_mut().enumerate() {
            let j = row0 + i;
            let row = &w[j * k..(j + 1) * k];
            let mut acc = [0.0f32; 4];
            for (wr, xr) in row.chunks_exact(4).zip(x.chunks_exact(4)) {
                acc[0] += wr[0] * xr[0];
                acc[1] += wr[1] * xr[1];
                acc[2] += wr[2] * xr[2];
                acc[3] += wr[3] * xr[3];
            }
            let mut tail = (acc[0] + acc[1]) + (acc[2] + acc[3]);
            let rem = k - k % 4;
            for (wi, xi) in row[rem..].iter().zip(&x[rem..]) {
                tail += wi * xi;
            }
            *o = (tail + bias[j]).max(0.0);
        }
    });
}

/// The seed interpreter's loops, kept verbatim as the correctness oracle
/// for property tests and the `*_naive` bench baselines.
pub mod naive {
    /// 3×3 same-padded strided conv + bias + ReLU, the original 6-deep
    /// `oy/ox/co/ky/kx/ci` loop nest.
    #[allow(clippy::too_many_arguments)]
    pub fn conv3x3(
        x: &[f32],
        w: &[f32],
        b: &[f32],
        h_in: usize,
        w_in: usize,
        c_in: usize,
        h_out: usize,
        w_out: usize,
        c_out: usize,
        stride: usize,
        out: &mut [f32],
    ) {
        for oy in 0..h_out {
            for ox in 0..w_out {
                for co in 0..c_out {
                    let mut acc = b[co];
                    for ky in 0..3usize {
                        for kx in 0..3usize {
                            let iy = (oy * stride + ky) as isize - 1;
                            let ix = (ox * stride + kx) as isize - 1;
                            if iy < 0 || ix < 0 || iy >= h_in as isize || ix >= w_in as isize {
                                continue;
                            }
                            let in_base = (iy as usize * w_in + ix as usize) * c_in;
                            let w_base = (co * 9 + ky * 3 + kx) * c_in;
                            for ci in 0..c_in {
                                acc += w[w_base + ci] * x[in_base + ci];
                            }
                        }
                    }
                    out[(oy * w_out + ox) * c_out + co] = acc.max(0.0);
                }
            }
        }
    }

    /// Full matmul + bias + ReLU, one serial dot per output.
    pub fn dense(x: &[f32], w: &[f32], b: &[f32], n_in: usize, n_out: usize, out: &mut [f32]) {
        for (j, o) in out.iter_mut().enumerate().take(n_out) {
            let row = &w[j * n_in..(j + 1) * n_in];
            let mut acc = b[j];
            for (wi, xi) in row.iter().zip(x) {
                acc += wi * xi;
            }
            *o = acc.max(0.0);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    fn randv(rng: &mut Pcg32, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.uniform(-1.0, 1.0) as f32).collect()
    }

    fn rel_close(a: &[f32], b: &[f32], tol: f32) -> bool {
        let scale = a
            .iter()
            .chain(b)
            .fold(1.0f32, |m, &v| m.max(v.abs()));
        a.iter().zip(b).all(|(p, q)| (p - q).abs() <= tol * scale)
    }

    #[test]
    fn gemm_matches_naive_dense_per_row() {
        // A·Bᵀ with M rows == running naive::dense per row of A
        let mut rng = Pcg32::seeded(1);
        for &(m, n, k) in &[(1usize, 5usize, 7usize), (4, 4, 16), (6, 9, 33), (13, 17, 8)] {
            let a = randv(&mut rng, m * k);
            let b = randv(&mut rng, n * k);
            let bias = randv(&mut rng, n);
            let mut fast = vec![0.0f32; m * n];
            gemm_bias_relu(&a, &b, &bias, m, n, k, &mut fast, 1);
            let mut want = vec![0.0f32; m * n];
            for r in 0..m {
                naive::dense(&a[r * k..(r + 1) * k], &b, &bias, k, n, &mut want[r * n..(r + 1) * n]);
            }
            assert!(rel_close(&fast, &want, 1e-5), "m={m} n={n} k={k}");
        }
    }

    #[test]
    fn gemm_thread_counts_bit_identical() {
        let mut rng = Pcg32::seeded(2);
        // large enough to clear MIN_PAR_ELEMS so threads actually spawn
        let (m, n, k) = (96, 96, 40);
        let a = randv(&mut rng, m * k);
        let b = randv(&mut rng, n * k);
        let bias = randv(&mut rng, n);
        let run = |threads| {
            let mut c = vec![0.0f32; m * n];
            gemm_bias_relu(&a, &b, &bias, m, n, k, &mut c, threads);
            c
        };
        let one = run(1);
        assert_eq!(one, run(2));
        assert_eq!(one, run(5));
    }

    #[test]
    fn gemv_matches_naive_dense() {
        let mut rng = Pcg32::seeded(3);
        for &(n, k) in &[(1usize, 1usize), (10, 36), (33, 50), (64, 128)] {
            let w = randv(&mut rng, n * k);
            let x = randv(&mut rng, k);
            let bias = randv(&mut rng, n);
            let mut fast = vec![0.0f32; n];
            gemv_bias_relu(&w, &x, &bias, n, k, &mut fast, 1);
            let mut want = vec![0.0f32; n];
            naive::dense(&x, &w, &bias, k, n, &mut want);
            assert!(rel_close(&fast, &want, 1e-5), "n={n} k={k}");
        }
    }

    #[test]
    fn im2col_gemm_matches_naive_conv() {
        let mut rng = Pcg32::seeded(4);
        for &(h, wd, ci, co, stride) in
            &[(5usize, 5usize, 3usize, 4usize, 1usize), (8, 6, 2, 5, 2), (4, 4, 1, 1, 1), (7, 9, 6, 3, 1)]
        {
            let (ho, wo) = (h.div_ceil(stride), wd.div_ceil(stride));
            let x = randv(&mut rng, h * wd * ci);
            let w = randv(&mut rng, co * 9 * ci);
            let b = randv(&mut rng, co);
            let mut want = vec![0.0f32; ho * wo * co];
            naive::conv3x3(&x, &w, &b, h, wd, ci, ho, wo, co, stride, &mut want);
            let mut patches = Vec::new();
            im2col_3x3(&x, h, wd, ci, ho, wo, stride, &mut patches);
            // patches · wᵀ is [positions, co] — same layout as the output
            let mut fast = vec![0.0f32; ho * wo * co];
            gemm_bias_relu(&patches, &w, &b, ho * wo, co, 9 * ci, &mut fast, 1);
            assert!(rel_close(&fast, &want, 1e-5), "h={h} w={wd} ci={ci} co={co} s={stride}");
        }
    }

    #[test]
    fn im2col_reuses_capacity() {
        let mut rng = Pcg32::seeded(5);
        let x = randv(&mut rng, 6 * 6 * 4);
        let mut patches = Vec::new();
        im2col_3x3(&x, 6, 6, 4, 6, 6, 1, &mut patches);
        let cap = patches.capacity();
        let ptr = patches.as_ptr();
        im2col_3x3(&x, 6, 6, 4, 6, 6, 1, &mut patches);
        assert_eq!(patches.capacity(), cap, "repacking must not grow");
        assert_eq!(patches.as_ptr(), ptr, "repacking must not reallocate");
    }

    #[test]
    fn padding_cells_stay_zero() {
        let x = vec![1.0f32; 3 * 3 * 2];
        let mut patches = Vec::new();
        im2col_3x3(&x, 3, 3, 2, 3, 3, 1, &mut patches);
        // top-left output position: ky=0 and kx=0 taps hang over the
        // border -> first 3 taps' channels all zero except (ky=1..)
        let k = 9 * 2;
        let row0 = &patches[0..k];
        assert_eq!(&row0[0..2], &[0.0, 0.0], "tap (ky=0, kx=0) padded");
        // tap index (ky*3 + kx) * c_in: tap 3 = (1,0) padded, tap 4 = (1,1) center
        assert_eq!(&row0[3 * 2..3 * 2 + 2], &[0.0, 0.0], "tap (ky=1, kx=0) padded");
        assert_eq!(&row0[4 * 2..4 * 2 + 2], &[1.0, 1.0], "tap (ky=1, kx=1) is real data");
    }
}
