//! PJRT engine (`--features xla`): compile HLO-text artifacts, execute
//! layer batches.
//!
//! Interchange is HLO *text*, not serialized protos: jax ≥ 0.5 emits
//! 64-bit instruction ids which xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see aot.py and /opt/xla-example/README.md).
//! Every artifact was lowered with `return_tuple=True`, so execution
//! unwraps a 1-tuple.
//!
//! The workspace links the vendored `third_party/xla` stub by default so
//! this module always *compiles*; executing requires patching in the
//! real `xla` crate (DESIGN.md §4).  [`Engine::cpu`] fails cleanly
//! against the stub, and the tests below skip themselves in that case.

use std::path::Path;

use anyhow::{bail, Context, Result};

use super::backend::{InferenceBackend, LayerExecutable, LayerSpec};

/// Shared PJRT CPU client.
pub struct Engine {
    client: xla::PjRtClient,
}

impl Engine {
    pub fn cpu() -> Result<Engine> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Engine { client })
    }

    /// Compile one layer artifact.  `in_shape`/`out_shape` are per-image
    /// activation shapes; the lowered module takes `[batch, *in_shape]`.
    pub fn compile_layer(
        &self,
        path: &Path,
        batch: usize,
        in_shape: &[usize],
        out_shape: &[usize],
    ) -> Result<LayerExec> {
        let sw = crate::serve::clock::Stopwatch::start();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        Ok(LayerExec {
            exe,
            batch,
            in_elems: batch * in_shape.iter().product::<usize>(),
            out_elems: batch * out_shape.iter().product::<usize>(),
            in_dims: std::iter::once(batch as i64)
                .chain(in_shape.iter().map(|&d| d as i64))
                .collect(),
            compile_ms: sw.elapsed_ms(),
        })
    }
}

impl InferenceBackend for Engine {
    fn name(&self) -> &'static str {
        "xla"
    }

    fn platform(&self) -> String {
        self.client.platform_name()
    }

    fn load_layer(&self, spec: &LayerSpec) -> Result<Box<dyn LayerExecutable>> {
        let path = spec
            .artifact
            .as_ref()
            .context("xla backend requires on-disk HLO artifacts (run `make artifacts`)")?;
        let exec = self.compile_layer(
            path,
            spec.batch,
            &spec.entry.in_shape,
            &spec.entry.out_shape,
        )?;
        Ok(Box::new(exec))
    }
}

/// One compiled layer executable.
pub struct LayerExec {
    exe: xla::PjRtLoadedExecutable,
    pub batch: usize,
    pub in_elems: usize,
    pub out_elems: usize,
    in_dims: Vec<i64>,
    /// PJRT compile time (ms) — reported by `dynasplit runtime-info`.
    pub compile_ms: f64,
}

impl LayerExec {
    /// Execute the layer on a flat `[batch, *in_shape]` activation.
    pub fn run(&self, input: &[f32]) -> Result<Vec<f32>> {
        if input.len() != self.in_elems {
            bail!(
                "layer expects {} input elements ({:?}), got {}",
                self.in_elems,
                self.in_dims,
                input.len()
            );
        }
        let literal = xla::Literal::vec1(input)
            .reshape(&self.in_dims)
            .context("reshaping input literal")?;
        let result = self.exe.execute::<xla::Literal>(&[literal])?[0][0]
            .to_literal_sync()
            .context("fetching result buffer")?;
        // aot.py lowers with return_tuple=True: unwrap the 1-tuple.
        let out = result.to_tuple1().context("unwrapping result tuple")?;
        let values = out.to_vec::<f32>().context("reading f32 result")?;
        if values.len() != self.out_elems {
            bail!(
                "layer produced {} elements, expected {}",
                values.len(),
                self.out_elems
            );
        }
        Ok(values)
    }
}

impl LayerExecutable for LayerExec {
    fn run(&self, input: &[f32]) -> Result<Vec<f32>> {
        LayerExec::run(self, input)
    }

    fn batch(&self) -> usize {
        self.batch
    }

    fn in_elems(&self) -> usize {
        self.in_elems
    }

    fn out_elems(&self) -> usize {
        self.out_elems
    }

    fn compile_ms(&self) -> f64 {
        self.compile_ms
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;
    use std::sync::atomic::{AtomicU64, Ordering};

    /// A tiny hand-written HLO module: f(x) = (x + 1,) over f32[2,3].
    /// Written as text exactly like the python-lowered artifacts, so this
    /// test exercises the whole load path without needing `make artifacts`.
    const ADD_ONE_HLO: &str = r#"
HloModule add_one, entry_computation_layout={(f32[2,3]{1,0})->(f32[2,3]{1,0})}

ENTRY main.5 {
  Arg_0.1 = f32[2,3]{1,0} parameter(0)
  constant.2 = f32[] constant(1)
  broadcast.3 = f32[2,3]{1,0} broadcast(constant.2), dimensions={}
  add.4 = f32[2,3]{1,0} add(Arg_0.1, broadcast.3)
  ROOT tuple.5 = (f32[2,3]{1,0}) tuple(add.4)
}
"#;

    /// Unique, self-deleting artifact file: pid + a process-wide counter
    /// make names collision-free across concurrent test binaries and
    /// repeated runs, and `Drop` cleans the temp dir up even on assertion
    /// failure (panics unwind through it).
    struct TmpArtifact(PathBuf);

    impl TmpArtifact {
        fn write(name: &str, text: &str) -> TmpArtifact {
            static SEQ: AtomicU64 = AtomicU64::new(0);
            let unique = format!(
                "dynasplit_{}_{}_{}.hlo.txt",
                name,
                std::process::id(),
                SEQ.fetch_add(1, Ordering::Relaxed)
            );
            let p = std::env::temp_dir().join(unique);
            std::fs::write(&p, text).unwrap();
            TmpArtifact(p)
        }

        fn path(&self) -> &Path {
            &self.0
        }
    }

    impl Drop for TmpArtifact {
        fn drop(&mut self) {
            let _ = std::fs::remove_file(&self.0);
        }
    }

    /// Engine, or a graceful skip when only the compile-only stub is
    /// linked (no PJRT runtime available).
    fn engine_or_skip(test: &str) -> Option<Engine> {
        match Engine::cpu() {
            Ok(e) => Some(e),
            Err(e) => {
                eprintln!("SKIPPED {test}: {e:#}");
                None
            }
        }
    }

    #[test]
    fn engine_loads_and_runs_hlo_text() {
        let Some(engine) = engine_or_skip("engine_loads_and_runs_hlo_text") else { return };
        assert!(engine.platform().to_lowercase().contains("cpu"));
        let artifact = TmpArtifact::write("add_one", ADD_ONE_HLO);
        let layer = engine.compile_layer(artifact.path(), 2, &[3], &[3]).unwrap();
        let out = layer.run(&[0.0, 1.0, 2.0, 3.0, 4.0, 5.0]).unwrap();
        assert_eq!(out, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert!(layer.compile_ms > 0.0);
    }

    #[test]
    fn wrong_input_length_rejected() {
        let Some(engine) = engine_or_skip("wrong_input_length_rejected") else { return };
        let artifact = TmpArtifact::write("add_one_b", ADD_ONE_HLO);
        let layer = engine.compile_layer(artifact.path(), 2, &[3], &[3]).unwrap();
        assert!(layer.run(&[1.0; 5]).is_err());
    }

    #[test]
    fn missing_artifact_errors_with_path() {
        let Some(engine) = engine_or_skip("missing_artifact_errors_with_path") else { return };
        let result = engine.compile_layer(Path::new("/nonexistent/layer.hlo.txt"), 1, &[1], &[1]);
        let err = match result {
            Err(e) => e,
            Ok(_) => panic!("expected load failure"),
        };
        assert!(format!("{err:#}").contains("layer.hlo.txt"));
    }

    #[test]
    fn malformed_hlo_rejected() {
        let Some(engine) = engine_or_skip("malformed_hlo_rejected") else { return };
        let artifact = TmpArtifact::write("garbage", "this is not hlo");
        assert!(engine.compile_layer(artifact.path(), 1, &[1], &[1]).is_err());
    }

    #[test]
    fn temp_artifacts_clean_up_after_themselves() {
        let path = {
            let artifact = TmpArtifact::write("cleanup_probe", "x");
            assert!(artifact.path().exists());
            artifact.path().to_path_buf()
        };
        assert!(!path.exists(), "temp artifact leaked at {}", path.display());
    }
}
