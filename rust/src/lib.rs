//! DynaSplit — energy-aware split inference on edge (paper reproduction).
//!
//! This crate is the Layer-3 coordinator of a three-layer stack:
//!
//! * **L1** — Pallas kernels (matmul / quantized matmul / fused attention),
//!   authored in `python/compile/kernels/`, lowered `interpret=True`.
//! * **L2** — per-layer JAX definitions of VGG16-mini and ViT-mini
//!   (`python/compile/model.py`), AOT-lowered layer-by-layer to HLO text
//!   by `python/compile/aot.py` into `artifacts/`.
//! * **L3** — this crate: the DynaSplit *Solver* (offline NSGA-III search
//!   over the hardware/software configuration space), *Controller*
//!   (online Algorithm-1 scheduling, configuration application, split
//!   execution over an edge↔cloud streaming transport), the concurrent
//!   *serving pipeline* ([`serve`]: bounded admission queue, pluggable
//!   scheduling policies, config-reuse caching workers), the
//!   *closed-loop adaptation layer* ([`adapt`]: serving telemetry,
//!   drift detection, online re-solve, live Pareto-store hot-swap,
//!   EWMA admission backpressure — DESIGN.md §11), plus every
//!   substrate the paper's testbed provided physically (DVFS'd edge CPU,
//!   Coral-style TPU, V100-style cloud GPU, power meters, network link) as
//!   a calibrated simulator.
//!
//! Python never runs on the request path: the rust binary instantiates
//! per-layer executables once at startup through a pluggable
//! [`runtime::InferenceBackend`] — the PJRT/XLA engine compiling the HLO
//! artifacts under `--features xla`, or the default dependency-free
//! reference interpreter — and is self-contained afterwards.
//!
//! See `DESIGN.md` for the system inventory, the backend feature matrix
//! (§4), and the experiment index that maps every figure/table of the
//! paper to a module + bench (§6).

pub mod util;
pub mod prop;
pub mod space;
pub mod nsga;
pub mod model;
pub mod simulator;
pub mod transport;
pub mod workload;
pub mod metrics;
pub mod runtime;
pub mod solver;
pub mod controller;
pub mod adapt;
pub mod fault;
pub mod serve;
pub mod obs;
pub mod experiments;
pub mod report; // (modules filled in build order; see DESIGN.md §7)

/// Crate-wide result type (anyhow-based; rich context on substrate errors).
pub type Result<T> = anyhow::Result<T>;

/// Default artifact directory, overridable with `--artifacts` / env.
pub const DEFAULT_ARTIFACTS: &str = "artifacts";

/// Resolve the artifact directory: CLI value, `DYNASPLIT_ARTIFACTS` env
/// var, or the default, in that order.
pub fn artifacts_dir(cli: Option<&str>) -> String {
    if let Some(dir) = cli {
        return dir.to_string();
    }
    std::env::var("DYNASPLIT_ARTIFACTS").unwrap_or_else(|_| DEFAULT_ARTIFACTS.to_string())
}
