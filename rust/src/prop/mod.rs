//! Mini property-based testing harness (proptest substitute).
//!
//! A property is a closure from a seeded [`Pcg32`] generator to `Result`;
//! the harness runs it over many cases and, on failure, reports the
//! failing case seed so it can be replayed deterministically:
//!
//! ```
//! use dynasplit::prop::{forall, Config};
//! forall("sorted stays sorted", Config::default(), |rng| {
//!     let mut v: Vec<u32> = (0..rng.below(50)).map(|_| rng.next_u32()).collect();
//!     v.sort_unstable();
//!     anyhow::ensure!(v.windows(2).all(|w| w[0] <= w[1]));
//!     Ok(())
//! });
//! ```

use crate::util::rng::Pcg32;

/// Harness configuration.
#[derive(Debug, Clone)]
pub struct Config {
    /// Number of generated cases.
    pub cases: u64,
    /// Base seed; each case uses `base_seed + case_index` so a reported
    /// failing seed reproduces with `replay`.
    pub base_seed: u64,
}

impl Default for Config {
    fn default() -> Self {
        // Env overrides let CI widen the sweep without code changes.
        let cases = std::env::var("DYNASPLIT_PROP_CASES")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(64);
        Config { cases, base_seed: 0xD15EA5E }
    }
}

/// Run `property` over `config.cases` seeded generators; panics with the
/// failing seed on the first violation.
pub fn forall<F>(name: &str, config: Config, mut property: F)
where
    F: FnMut(&mut Pcg32) -> anyhow::Result<()>,
{
    for case in 0..config.cases {
        let seed = config.base_seed.wrapping_add(case);
        let mut rng = Pcg32::new(seed, 54);
        if let Err(e) = property(&mut rng) {
            panic!(
                "property {name:?} failed on case {case} (seed {seed:#x}):\n{e:#}\n\
                 replay with prop::replay({seed:#x}, ...)"
            );
        }
    }
}

/// Re-run a single failing case by seed (debugging aid).
pub fn replay<F>(seed: u64, mut property: F)
where
    F: FnMut(&mut Pcg32) -> anyhow::Result<()>,
{
    let mut rng = Pcg32::new(seed, 54);
    property(&mut rng).expect("replayed property still fails");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        forall("tautology", Config { cases: 16, base_seed: 1 }, |rng| {
            let x = rng.f64();
            anyhow::ensure!((0.0..1.0).contains(&x));
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "property \"always fails\"")]
    fn failing_property_reports_seed() {
        forall("always fails", Config { cases: 4, base_seed: 2 }, |_| {
            anyhow::bail!("nope")
        });
    }

    #[test]
    fn cases_are_distinct() {
        let mut seen = std::collections::HashSet::new();
        forall("distinct", Config { cases: 32, base_seed: 3 }, |rng| {
            seen.insert(rng.next_u64());
            Ok(())
        });
        assert_eq!(seen.len(), 32);
    }
}
