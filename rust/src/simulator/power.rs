//! Instantaneous power model for both nodes.
//!
//! Edge (RPi 4B): P = P_idle + c·f³ while the CPU computes, plus the TPU
//! contribution when attached/active (the testbed powers the USB port off
//! when the TPU is unused, §6.1).  Cloud (Grid'5000 node): node-level
//! power during the active tail-compute window only, matching the paper's
//! energy accounting (§3.4: cloud energy integrated over [t_net1, t_net2]).

use super::calib::*;
use crate::space::{Config, TpuMode};

/// What the edge node is doing at an instant (drives its power draw).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EdgeState {
    /// Waiting (e.g. during network transfer or the cloud phase).
    Idle,
    /// Executing head layers on the CPU.
    CpuBusy,
    /// Executing quantized head layers on the TPU (CPU mostly orchestrates).
    TpuBusy,
}

/// Edge power (W) for a state under a configuration.
pub fn edge_power(state: EdgeState, config: &Config) -> f64 {
    let f = config.cpu_ghz();
    let cpu_active = EDGE_CPU_CUBIC_W_PER_GHZ3 * f * f * f;
    // TPU contribution: off = unpowered USB port; attached (std/max) draws
    // idle power whenever the edge node is up, more when active.
    let tpu_attached = match config.tpu {
        TpuMode::Off => 0.0,
        _ => TPU_IDLE_ATTACHED_W,
    };
    match state {
        EdgeState::Idle => EDGE_IDLE_W + tpu_attached,
        EdgeState::CpuBusy => EDGE_IDLE_W + cpu_active + tpu_attached,
        EdgeState::TpuBusy => {
            let tpu_active = match config.tpu {
                TpuMode::Off => 0.0, // unreachable in practice
                TpuMode::Std => TPU_ACTIVE_STD_W,
                TpuMode::Max => TPU_ACTIVE_MAX_W,
            };
            // CPU orchestrates DMA at ~20% of its active power.
            EDGE_IDLE_W + 0.2 * cpu_active + tpu_active
        }
    }
}

/// Cloud node power (W) during active tail computation.
pub fn cloud_power(config: &Config) -> f64 {
    if config.gpu {
        CLOUD_GPU_ACTIVE_W
    } else {
        CLOUD_CPU_ACTIVE_W
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::space::{Config, Network};

    fn cfg(cpu_idx: usize, tpu: TpuMode, gpu: bool) -> Config {
        Config { net: Network::Vgg16, cpu_idx, tpu, gpu, split: 11 }
    }

    #[test]
    fn busy_exceeds_idle() {
        let c = cfg(6, TpuMode::Off, false);
        assert!(edge_power(EdgeState::CpuBusy, &c) > edge_power(EdgeState::Idle, &c));
    }

    #[test]
    fn power_rises_with_frequency() {
        let mut last = 0.0;
        for cpu_idx in 0..7 {
            let p = edge_power(EdgeState::CpuBusy, &cfg(cpu_idx, TpuMode::Off, false));
            assert!(p > last);
            last = p;
        }
    }

    #[test]
    fn tpu_off_draws_nothing_extra_idle() {
        let off = edge_power(EdgeState::Idle, &cfg(6, TpuMode::Off, false));
        let max = edge_power(EdgeState::Idle, &cfg(6, TpuMode::Max, false));
        assert_eq!(off, EDGE_IDLE_W);
        assert!(max > off); // attached TPU draws idle power
    }

    #[test]
    fn tpu_busy_beats_cpu_busy_in_power_but_not_3x() {
        // Fig 2c: TPU *draws more power* yet total energy is ~3x lower due
        // to speed; power itself must be in the same ballpark.
        let c = cfg(6, TpuMode::Max, false);
        let tpu = edge_power(EdgeState::TpuBusy, &c);
        let cpu = edge_power(EdgeState::CpuBusy, &c);
        assert!(tpu > 0.8 * cpu && tpu < 2.0 * cpu, "tpu {tpu} cpu {cpu}");
    }

    #[test]
    fn cloud_gpu_hotter_than_cpu() {
        assert!(cloud_power(&cfg(6, TpuMode::Off, true)) > cloud_power(&cfg(6, TpuMode::Off, false)));
    }
}
