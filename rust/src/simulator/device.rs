//! Per-segment latency model: edge CPU (DVFS), edge TPU, cloud GPU/CPU.
//!
//! Latency of a segment = Σ_layers macs / rate(device, config), with the
//! rates derived in [`calib`] from the paper's end-to-end numbers.  The
//! model captures the paper's structure exactly:
//!
//!   T_inf(x) = T_edge(x) + T_net(x) + T_cloud(x)            (§3.3)
//!
//! with the special cases k=0 (edge does only request prep) and k=L
//! (no network, no cloud).

use super::calib::{self, Calib};
use crate::model::NetCost;
use crate::space::{Config, TpuMode};

/// Device-model for one network (rates are per-network; see calib.rs).
#[derive(Debug, Clone)]
pub struct DeviceModel {
    pub cost: NetCost,
    pub calib: Calib,
    edge_cpu_rate_max: f64,
    edge_tpu_rate_max: f64,
    cloud_gpu_rate: f64,
    cloud_cpu_rate: f64,
}

/// Latency decomposition of a single inference (seconds).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LatencyBreakdown {
    pub edge_s: f64,
    pub net_s: f64,
    pub cloud_s: f64,
    /// Of `edge_s`, the portion spent on the TPU (drives TPU power).
    pub edge_tpu_s: f64,
}

impl LatencyBreakdown {
    pub fn total_s(&self) -> f64 {
        self.edge_s + self.net_s + self.cloud_s
    }
}

impl DeviceModel {
    pub fn new(cost: NetCost) -> DeviceModel {
        let calib = Calib::for_network(cost.net);
        DeviceModel {
            edge_cpu_rate_max: calib.edge_cpu_rate(&cost),
            edge_tpu_rate_max: if cost.net.tpu_capable() {
                calib.edge_tpu_rate(&cost)
            } else {
                f64::NAN
            },
            cloud_gpu_rate: calib.cloud_gpu_rate(&cost),
            cloud_cpu_rate: calib.cloud_cpu_rate(&cost),
            cost,
            calib,
        }
    }

    /// Thermal-throttle the edge: scale the CPU and TPU rates by
    /// `factor` (< 1 slows the device).  The paper's RPi testbed
    /// throttles under sustained load; the adaptation experiments step
    /// a cloned testbed's device model mid-run with this and let the
    /// closed loop detect the resulting latency/energy drift.
    pub fn throttle_edge(&mut self, factor: f64) {
        assert!(factor > 0.0, "throttle factor must be positive");
        self.edge_cpu_rate_max *= factor;
        if self.edge_tpu_rate_max.is_finite() {
            self.edge_tpu_rate_max *= factor;
        }
    }

    /// Edge CPU rate at the configured DVFS frequency:
    /// rate(f) = rate(f_max) · (f / f_max)^alpha.
    fn edge_cpu_rate(&self, cpu_ghz: f64) -> f64 {
        let f_max = *crate::space::CPU_FREQS_GHZ.last().unwrap();
        self.edge_cpu_rate_max * (cpu_ghz / f_max).powf(self.calib.dvfs_alpha)
    }

    fn edge_tpu_rate(&self, tpu: TpuMode) -> f64 {
        match tpu {
            TpuMode::Off => f64::NAN,
            TpuMode::Std => self.edge_tpu_rate_max * self.calib.tpu_std_factor,
            TpuMode::Max => self.edge_tpu_rate_max,
        }
    }

    /// Deterministic (noise-free) latency breakdown for one inference.
    pub fn latency(&self, config: &Config) -> LatencyBreakdown {
        let l = self.cost.num_layers();
        let k = config.split.min(l);
        let cpu_rate = self.edge_cpu_rate(config.cpu_ghz());
        let f_scale = cpu_rate / self.edge_cpu_rate_max; // prep scales too

        // --- edge segment: layers < k, TPU-eligible layers on the TPU ---
        let mut edge_s = self.calib.edge_prep_s / f_scale;
        let mut edge_tpu_s = 0.0;
        let tpu_on = config.tpu != TpuMode::Off && self.cost.net.tpu_capable();
        for layer in &self.cost.layers[..k] {
            if tpu_on && layer.quantizable {
                let t = layer.macs as f64 / self.edge_tpu_rate(config.tpu);
                edge_s += t;
                edge_tpu_s += t;
            } else {
                edge_s += layer.macs as f64 / cpu_rate;
            }
        }

        // --- network + cloud segments ---
        let (net_s, cloud_s) = if k >= l {
            (0.0, 0.0) // edge-only: no transfer, no cloud (§3.3 case ii)
        } else {
            let bytes = self.cost.transfer_bytes(k) + self.cost.result_bytes();
            let net_s = calib::LINK_RTT_S + bytes as f64 / calib::LINK_BYTES_PER_S;
            let rate = if config.gpu { self.cloud_gpu_rate } else { self.cloud_cpu_rate };
            let cloud_s = self.calib.cloud_prep_s + self.cost.tail_macs(k) as f64 / rate;
            (net_s, cloud_s)
        };
        LatencyBreakdown { edge_s, net_s, cloud_s, edge_tpu_s }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::space::{Network, Space};

    fn model(net: Network) -> DeviceModel {
        DeviceModel::new(NetCost::of(net))
    }

    fn cfg(net: Network, cpu_idx: usize, tpu: TpuMode, gpu: bool, split: usize) -> Config {
        crate::space::feasible::repair(Config { net, cpu_idx, tpu, gpu, split })
    }

    #[test]
    fn edge_only_has_no_net_or_cloud() {
        let m = model(Network::Vgg16);
        let b = m.latency(&cfg(Network::Vgg16, 6, TpuMode::Max, false, 22));
        assert_eq!(b.net_s, 0.0);
        assert_eq!(b.cloud_s, 0.0);
        assert!(b.edge_s > 0.0);
    }

    #[test]
    fn cloud_only_has_minimal_edge() {
        let m = model(Network::Vgg16);
        let b = m.latency(&cfg(Network::Vgg16, 6, TpuMode::Off, true, 0));
        assert!(b.edge_s < 0.010, "only prep expected, got {}", b.edge_s);
        assert!(b.cloud_s > 0.0 && b.net_s > 0.0);
    }

    #[test]
    fn calibration_endpoints_vgg() {
        let m = model(Network::Vgg16);
        // edge-only fp32 at 1.8 GHz ≈ 1.676 s target (+prep)
        let b = m.latency(&cfg(Network::Vgg16, 6, TpuMode::Off, false, 22));
        assert!((b.total_s() - 1.681).abs() < 0.02, "{}", b.total_s());
        // edge-only TPU max ≈ 0.425 s target
        let b = m.latency(&cfg(Network::Vgg16, 6, TpuMode::Max, false, 22));
        assert!((b.total_s() - 0.430).abs() < 0.02, "{}", b.total_s());
        // cloud-only GPU ≈ 96 ms (§6.3.1 median)
        let b = m.latency(&cfg(Network::Vgg16, 6, TpuMode::Off, true, 0));
        assert!((b.total_s() - 0.096).abs() < 0.012, "{}", b.total_s());
    }

    #[test]
    fn calibration_endpoints_vit() {
        let m = model(Network::Vit);
        let b = m.latency(&cfg(Network::Vit, 6, TpuMode::Off, false, 19));
        assert!((b.total_s() - 3.931).abs() < 0.03, "{}", b.total_s());
        let b = m.latency(&cfg(Network::Vit, 6, TpuMode::Off, true, 0));
        assert!((b.total_s() - 0.118).abs() < 0.012, "{}", b.total_s());
    }

    #[test]
    fn table2_max_latency_scale() {
        // Table 2: VGG16 max 5,026.8 ms at CPU 0.6, no TPU, no GPU, k=20.
        let m = model(Network::Vgg16);
        let b = m.latency(&cfg(Network::Vgg16, 0, TpuMode::Off, false, 20));
        assert!((4.2..6.2).contains(&b.total_s()), "{}", b.total_s());
        // ViT max 10,287.6 ms at 0.6 GHz, k=18.
        let m = model(Network::Vit);
        let b = m.latency(&cfg(Network::Vit, 0, TpuMode::Off, false, 18));
        assert!((9.0..13.0).contains(&b.total_s()), "{}", b.total_s());
    }

    #[test]
    fn throttled_edge_is_slower_cloud_untouched() {
        let mut m = model(Network::Vgg16);
        let c = cfg(Network::Vgg16, 6, TpuMode::Max, true, 11);
        let before = m.latency(&c);
        m.throttle_edge(0.5);
        let after = m.latency(&c);
        assert!(after.edge_s > before.edge_s * 1.8, "edge slowed ~2x");
        assert!(after.edge_tpu_s > before.edge_tpu_s * 1.8, "TPU throttles too");
        assert_eq!(after.cloud_s, before.cloud_s, "cloud unaffected");
        assert_eq!(after.net_s, before.net_s);
    }

    #[test]
    fn latency_decreases_with_frequency() {
        let m = model(Network::Vgg16);
        let mut last = f64::INFINITY;
        for cpu_idx in 0..7 {
            let b = m.latency(&cfg(Network::Vgg16, cpu_idx, TpuMode::Off, false, 22));
            assert!(b.total_s() < last);
            last = b.total_s();
        }
    }

    #[test]
    fn gpu_faster_than_cloud_cpu() {
        let m = model(Network::Vit);
        let g = m.latency(&cfg(Network::Vit, 6, TpuMode::Off, true, 0));
        let c = m.latency(&cfg(Network::Vit, 6, TpuMode::Off, false, 0));
        assert!(c.cloud_s > 3.0 * g.cloud_s);
    }

    #[test]
    fn tpu_accelerates_vgg_only() {
        let m = model(Network::Vgg16);
        let off = m.latency(&cfg(Network::Vgg16, 6, TpuMode::Off, false, 22));
        let max = m.latency(&cfg(Network::Vgg16, 6, TpuMode::Max, false, 22));
        let std = m.latency(&cfg(Network::Vgg16, 6, TpuMode::Std, false, 22));
        assert!(max.total_s() < off.total_s() / 2.0);
        // Fig 2c: std ≈ max (no significant difference)
        assert!((std.total_s() - max.total_s()).abs() / max.total_s() < 0.15);
    }

    #[test]
    fn split_latency_between_extremes_somewhere() {
        // Fig 2b: split latency is non-monotone but some split beats the
        // worse extreme.
        let m = model(Network::Vgg16);
        let space = Space::new(Network::Vgg16);
        let lat = |k| {
            m.latency(&crate::space::feasible::repair(space.decode(&[6, 2, 1, k]))).total_s()
        };
        let edge_only = lat(22);
        let any_split_better = (1..22).any(|k| lat(k) < edge_only);
        assert!(any_split_better);
    }

    #[test]
    fn transfer_bytes_drive_net_time() {
        let m = model(Network::Vgg16);
        // split after conv_00 (64 KiB/image) must cost more net time than
        // after pool_17-ish small tensors
        let early = m.latency(&cfg(Network::Vgg16, 6, TpuMode::Off, true, 1));
        let late = m.latency(&cfg(Network::Vgg16, 6, TpuMode::Off, true, 19));
        assert!(early.net_s > late.net_s);
    }
}
