//! Calibration constants — every value is tied to a number in the paper.
//!
//! The simulator expresses per-segment latency as `work / rate` where
//! `work` is the segment's MAC count (from `model::meta`) and `rate` is a
//! device-and-network-specific MAC throughput.  Rates are *derived from
//! the paper's reported end-to-end latencies* and our mini networks'
//! total MAC counts, so the simulated end-to-end numbers land on the
//! paper's scale by construction and everything in between (split points,
//! DVFS sweeps) follows from the model.  Per-network rates are separate
//! because the paper's two networks run on different software stacks
//! (TFLite-optimized CNN vs un-optimized fp32 transformer — §5), which is
//! exactly why the paper found layer-wise runtime hard to predict.

use crate::model::NetCost;
use crate::space::Network;

/// Calibration target table (paper sources in comments).
#[derive(Debug, Clone)]
pub struct Calib {
    // ----- latency targets (seconds, per single inference) -----
    /// Edge-only fp32 full network at 1.8 GHz.
    /// VGG16: Table 2 max 5,026.8 ms at 0.6 GHz ⇒ ~1.676 s at 1.8 GHz with
    /// the 1/f model. ViT: §6.3.1 edge baseline median 3.926 s (ViT's edge
    /// baseline has no TPU, CPU at max).
    pub edge_full_fp32_s: f64,
    /// Edge-only with TPU at max on the quantizable layers.
    /// VGG16: §6.3.1 edge baseline median 425 ms. (ViT: unused.)
    pub edge_full_tpu_s: f64,
    /// Cloud GPU compute time for the full network (excluding transfer).
    /// Derived from the §6.3.1 cloud medians (96 ms VGG / 117 ms ViT)
    /// minus the modeled edge-prep + network time (~31 ms).
    pub cloud_full_gpu_s: f64,

    // ----- hardware behaviour -----
    /// TPU std (250 MHz) rate relative to max (500 MHz).  Fig. 2c: "no
    /// significant differences" between std and max for this network —
    /// the TPU is memory/IO bound, not clock bound, so we use 0.93.
    pub tpu_std_factor: f64,
    /// Cloud CPU (GPU = no) slowdown vs GPU.  Fig. 2d: GPU acceleration
    /// "significantly decreases" latency; V100 vs 2×Xeon on CNN inference
    /// is typically ~6×.
    pub cloud_cpu_slowdown: f64,
    /// Latency ∝ (f_max / f)^alpha for edge DVFS.  Fig. 2a shows close to
    /// proportional scaling (compute-bound inference).
    pub dvfs_alpha: f64,

    // ----- fixed latency components -----
    /// Edge-side request preparation (image scaling, batch creation — the
    /// paper's "minimal processing" that remains even cloud-only, §3.3),
    /// at 1.8 GHz; scales with DVFS like compute.
    pub edge_prep_s: f64,
    /// Cloud-side deserialization + output decoding (§6.2.2).
    pub cloud_prep_s: f64,
}

impl Calib {
    pub fn for_network(net: Network) -> Calib {
        match net {
            Network::Vgg16 => Calib {
                edge_full_fp32_s: 1.676,
                edge_full_tpu_s: 0.425,
                cloud_full_gpu_s: 0.065,
                ..Calib::common()
            },
            Network::Vit => Calib {
                edge_full_fp32_s: 3.926,
                edge_full_tpu_s: f64::NAN, // ViT never runs on the TPU
                cloud_full_gpu_s: 0.087,
                ..Calib::common()
            },
        }
    }

    fn common() -> Calib {
        Calib {
            edge_full_fp32_s: f64::NAN,
            edge_full_tpu_s: f64::NAN,
            cloud_full_gpu_s: f64::NAN,
            tpu_std_factor: 0.93,
            cloud_cpu_slowdown: 6.0,
            dvfs_alpha: 1.0,
            edge_prep_s: 0.005,
            cloud_prep_s: 0.004,
        }
    }

    // ------------------------------------------------------------------
    // Derived MAC rates
    // ------------------------------------------------------------------

    /// Edge CPU MAC rate at 1.8 GHz (fp32 path).
    pub fn edge_cpu_rate(&self, cost: &NetCost) -> f64 {
        cost.total_macs() as f64 / self.edge_full_fp32_s
    }

    /// Edge TPU MAC rate at 500 MHz over the quantizable layers (the
    /// non-quantizable layers still run on the CPU at 1.8 GHz when the
    /// edge baseline is measured).
    pub fn edge_tpu_rate(&self, cost: &NetCost) -> f64 {
        let quant_macs: u64 =
            cost.layers.iter().filter(|l| l.quantizable).map(|l| l.macs).sum();
        let cpu_macs = cost.total_macs() - quant_macs;
        let cpu_rate = self.edge_cpu_rate(cost);
        let cpu_time = cpu_macs as f64 / cpu_rate;
        let tpu_time = (self.edge_full_tpu_s - cpu_time).max(1e-4);
        quant_macs as f64 / tpu_time
    }

    /// Cloud GPU MAC rate.
    pub fn cloud_gpu_rate(&self, cost: &NetCost) -> f64 {
        cost.total_macs() as f64 / self.cloud_full_gpu_s
    }

    pub fn cloud_cpu_rate(&self, cost: &NetCost) -> f64 {
        self.cloud_gpu_rate(cost) / self.cloud_cpu_slowdown
    }
}

// ---------------------------------------------------------------------
// Power model constants (see power.rs for the model itself)
// ---------------------------------------------------------------------

/// RPi 4B idle, WiFi/BT/LEDs disabled (§6.1): ≈2.7 W.
pub const EDGE_IDLE_W: f64 = 2.7;
/// Cubic DVFS coefficient.  Full-load power at 1.8 GHz = 2.7 + c·1.8³ ≈
/// 4.0 W (RPi 4B CPU-stress scale).  c is chosen just below the monotone
/// bound c < P_idle/(2·f_max³) ≈ 0.232 so the energy-vs-frequency curve
/// is decreasing over the whole 0.6–1.8 GHz range but flattens at the
/// top — exactly Fig. 2a's observed shape.
pub const EDGE_CPU_CUBIC_W_PER_GHZ3: f64 = 0.22;
/// Coral USB accelerator active power: ≈2.2 W at 500 MHz (max),
/// ≈1.8 W at 250 MHz (std); ≈0.9 W attached-idle.  The testbed powers the
/// USB port off when the TPU is unused (§6.1), so `off` draws nothing.
pub const TPU_ACTIVE_MAX_W: f64 = 2.2;
pub const TPU_ACTIVE_STD_W: f64 = 1.8;
pub const TPU_IDLE_ATTACHED_W: f64 = 0.9;
/// Grid'5000 node (2×Xeon E5-2698v4 + 512 GiB + V100 active), node-level
/// wattmeter: ≈1,000 W under GPU inference — consistent with the paper's
/// ~68 J per 65 ms active window (§6.3.2).
pub const CLOUD_GPU_ACTIVE_W: f64 = 1000.0;
/// Cloud CPU-only inference: CPUs loaded, GPU idle ≈ 400 W.
pub const CLOUD_CPU_ACTIVE_W: f64 = 400.0;

// ---------------------------------------------------------------------
// Network link (edge in Vienna ↔ Grid'5000 in France, §6.1)
// ---------------------------------------------------------------------

/// Round-trip time of the edge↔cloud link.
pub const LINK_RTT_S: f64 = 0.020;
/// Sustained throughput (100 Mbit/s ⇒ 12.5 MB/s).
pub const LINK_BYTES_PER_S: f64 = 12.5e6;

// ---------------------------------------------------------------------
// Power meters (§6.1)
// ---------------------------------------------------------------------

/// GW-Instek GPM-8213 on the edge node: 200 ms sampling.
pub const EDGE_METER_PERIOD_S: f64 = 0.200;
/// Omegawatt on the cloud node: 20 ms sampling.
pub const CLOUD_METER_PERIOD_S: f64 = 0.020;
/// Meter amplitude noise (fraction of reading): resolution + mains jitter.
pub const METER_NOISE_FRAC: f64 = 0.02;

// ---------------------------------------------------------------------
// Measurement noise
// ---------------------------------------------------------------------

/// Log-normal sigma of per-inference latency jitter (OS scheduling etc.).
pub const LATENCY_JITTER_SIGMA: f64 = 0.04;
/// The paper observed unexplained outliers at 800 MHz "despite multiple
/// runs" (Fig. 2a): we reproduce them as a 12% chance of a 1.5× latency
/// spike at that frequency step only.
pub const OUTLIER_800MHZ_P: f64 = 0.12;
pub const OUTLIER_800MHZ_FACTOR: f64 = 1.5;
/// Accuracy measurement jitter (per-trial resampling of the eval batch).
pub const ACCURACY_JITTER: f64 = 0.002;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::NetCost;

    #[test]
    fn rates_positive_and_ordered() {
        for net in Network::ALL {
            let cost = NetCost::of(net);
            let c = Calib::for_network(net);
            let cpu = c.edge_cpu_rate(&cost);
            let gpu = c.cloud_gpu_rate(&cost);
            assert!(cpu > 0.0 && gpu > cpu, "{net:?}: cpu {cpu} gpu {gpu}");
            assert!(c.cloud_cpu_rate(&cost) < gpu);
        }
    }

    #[test]
    fn vgg_tpu_faster_than_cpu() {
        let cost = NetCost::of(Network::Vgg16);
        let c = Calib::for_network(Network::Vgg16);
        assert!(c.edge_tpu_rate(&cost) > 2.0 * c.edge_cpu_rate(&cost));
    }

    #[test]
    fn edge_energy_curve_monotone_decreasing() {
        // Fig. 2a: energy decreases with CPU frequency, flattening at the
        // top — verify the power constants produce that shape.
        let mut last = f64::INFINITY;
        for &f in &crate::space::CPU_FREQS_GHZ {
            let p = EDGE_IDLE_W + EDGE_CPU_CUBIC_W_PER_GHZ3 * f * f * f;
            let t = 1.0 / f; // relative latency (alpha = 1)
            let e = p * t;
            assert!(e < last, "energy rose at {f} GHz: {e} >= {last}");
            last = e;
        }
    }

    #[test]
    fn cloud_energy_matches_paper_scale() {
        // ~65 ms GPU window at ~1 kW ≈ 65 J ≈ paper's 68 J median (VGG16).
        let c = Calib::for_network(Network::Vgg16);
        let e = c.cloud_full_gpu_s * CLOUD_GPU_ACTIVE_W;
        assert!((50.0..90.0).contains(&e), "cloud energy {e} J");
    }

    #[test]
    fn edge_tpu_energy_matches_paper_scale() {
        // §6.3.2: VGG edge baseline < 3 J.
        let c = Calib::for_network(Network::Vgg16);
        let p = EDGE_IDLE_W
            + EDGE_CPU_CUBIC_W_PER_GHZ3 * 1.8f64.powi(3) * 0.2 // CPU mostly idle
            + TPU_ACTIVE_MAX_W;
        let e = c.edge_full_tpu_s * p;
        assert!(e < 3.0, "edge TPU energy {e} J");
    }
}
