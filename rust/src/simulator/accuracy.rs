//! Accuracy lookup for trials.
//!
//! Accuracy depends only on (network, TPU-used, split point): quantized
//! head layers perturb logits, fp32 layers do not (§2.2, Fig. 2e).  The
//! table comes from either the manifest's python-oracle expectations or a
//! PJRT-measured cache produced by the rust runtime (`runtime::evaluate`);
//! per-trial jitter models re-sampling the evaluation images.

use anyhow::Result;

use crate::model::manifest::Manifest;
use crate::space::{Config, Network, TpuMode};
use crate::util::rng::Pcg32;

/// Accuracy table for both networks.
#[derive(Debug, Clone)]
pub struct AccuracyTable {
    vgg_fp32: f64,
    /// `vgg_int8_prefix[k]`: layers < k quantized (TPU head), rest fp32.
    vgg_int8_prefix: Vec<f64>,
    vit_fp32: f64,
}

impl AccuracyTable {
    /// Build from manifest expectations (python oracle path).
    pub fn from_manifest(m: &Manifest) -> Result<AccuracyTable> {
        let prefix = m
            .vgg16
            .expected_accuracy
            .int8_prefix
            .clone()
            .ok_or_else(|| anyhow::anyhow!("manifest lacks vgg16 int8_prefix accuracies"))?;
        Ok(AccuracyTable {
            vgg_fp32: m.vgg16.expected_accuracy.fp32,
            vgg_int8_prefix: prefix,
            vit_fp32: m.vit.expected_accuracy.fp32,
        })
    }

    /// Build from explicitly measured values (rust runtime evaluation).
    pub fn from_values(vgg_fp32: f64, vgg_int8_prefix: Vec<f64>, vit_fp32: f64) -> AccuracyTable {
        assert_eq!(vgg_int8_prefix.len(), Network::Vgg16.num_layers() + 1);
        AccuracyTable { vgg_fp32, vgg_int8_prefix, vit_fp32 }
    }

    /// Synthetic stand-in used by tests and simulator-only runs without
    /// artifacts: fp32 ≈ 95.3%, with a gentle sub-percent dip as more
    /// layers are quantized (the Fig. 2e shape).
    pub fn synthetic() -> AccuracyTable {
        let l = Network::Vgg16.num_layers();
        let prefix = (0..=l)
            .map(|k| 0.953 - 0.004 * (k as f64 / l as f64) - 0.002 * ((k * 7 % 5) as f64 / 5.0))
            .collect();
        AccuracyTable { vgg_fp32: 0.953, vgg_int8_prefix: prefix, vit_fp32: 0.945 }
    }

    /// Noise-free accuracy for a configuration.
    pub fn accuracy(&self, config: &Config) -> f64 {
        match config.net {
            Network::Vit => self.vit_fp32,
            Network::Vgg16 => {
                if config.tpu == TpuMode::Off {
                    self.vgg_fp32
                } else {
                    // head (layers < k) runs quantized on the TPU
                    self.vgg_int8_prefix[config.split.min(self.vgg_int8_prefix.len() - 1)]
                }
            }
        }
    }

    /// Accuracy with per-trial measurement jitter, clamped to [0, 1].
    pub fn sample(&self, config: &Config, rng: &mut Pcg32) -> f64 {
        (self.accuracy(config) + rng.gaussian(0.0, super::calib::ACCURACY_JITTER))
            .clamp(0.0, 1.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(net: Network, tpu: TpuMode, split: usize) -> Config {
        Config { net, cpu_idx: 6, tpu, gpu: false, split }
    }

    #[test]
    fn tpu_off_gives_fp32() {
        let t = AccuracyTable::synthetic();
        assert_eq!(t.accuracy(&cfg(Network::Vgg16, TpuMode::Off, 11)), 0.953);
    }

    #[test]
    fn quantized_prefix_dips_subpercent() {
        let t = AccuracyTable::synthetic();
        let fp32 = t.accuracy(&cfg(Network::Vgg16, TpuMode::Off, 22));
        let q_full = t.accuracy(&cfg(Network::Vgg16, TpuMode::Max, 22));
        assert!(q_full < fp32);
        assert!(fp32 - q_full < 0.01, "paper: sub-percent deltas");
    }

    #[test]
    fn vit_ignores_tpu_and_split() {
        let t = AccuracyTable::synthetic();
        let a = t.accuracy(&cfg(Network::Vit, TpuMode::Off, 0));
        let b = t.accuracy(&cfg(Network::Vit, TpuMode::Off, 19));
        assert_eq!(a, b);
    }

    #[test]
    fn sample_stays_close_and_bounded() {
        let t = AccuracyTable::synthetic();
        let mut rng = Pcg32::seeded(5);
        let c = cfg(Network::Vgg16, TpuMode::Max, 8);
        let base = t.accuracy(&c);
        for _ in 0..1_000 {
            let s = t.sample(&c, &mut rng);
            assert!((0.0..=1.0).contains(&s));
            assert!((s - base).abs() < 0.012);
        }
    }

    #[test]
    fn from_values_validates_length() {
        let r = std::panic::catch_unwind(|| {
            AccuracyTable::from_values(0.9, vec![0.9; 5], 0.9)
        });
        assert!(r.is_err());
    }
}
