//! Sampling-limited power meters + trapezoidal energy integration.
//!
//! The paper cannot observe per-inference energy directly: the edge meter
//! (GPM-8213) samples every 200 ms and a single inference can be faster
//! than that, which is *why* the evaluation batches 1,000 inferences per
//! request (§6.2.2 "Energy Consumption").  We reproduce the measurement
//! chain faithfully: the simulated node emits a piecewise-constant power
//! trace; the meter samples it at its real period with amplitude noise;
//! energy is the trapezoidal integral of the samples — so short trials
//! have honestly noisy energy readings, exactly like the testbed.

use crate::util::rng::Pcg32;
use crate::util::stats;

/// A piecewise-constant power trace: (duration_s, watts) segments.
///
/// Segment *start* times are maintained incrementally so `power_at` is a
/// binary search — §Perf L3 item 1: the original linear scan made meter
/// sampling O(samples × segments), which dominated solver time on long
/// multi-segment trials (1.60 ms → 0.17 ms on the 2,000-segment micro
/// bench; see EXPERIMENTS.md §Perf).
#[derive(Debug, Clone, Default)]
pub struct PowerTrace {
    /// (start_s, duration_s, watts), starts strictly increasing.
    segments: Vec<(f64, f64, f64)>,
    total: f64,
}

impl PowerTrace {
    pub fn new() -> Self {
        Self::default()
    }

    /// Append a segment of `dur_s` seconds at `watts`.
    pub fn push(&mut self, dur_s: f64, watts: f64) {
        if dur_s > 0.0 {
            self.segments.push((self.total, dur_s, watts));
            self.total += dur_s;
        }
    }

    pub fn total_duration(&self) -> f64 {
        self.total
    }

    /// True (unobservable) energy in joules: Σ P·dt.
    pub fn true_energy_j(&self) -> f64 {
        self.segments.iter().map(|s| s.1 * s.2).sum()
    }

    /// Power at absolute time `t` (0 outside the trace).
    pub fn power_at(&self, t: f64) -> f64 {
        if t < 0.0 || t >= self.total {
            return 0.0;
        }
        // last segment with start <= t
        let idx = self.segments.partition_point(|&(start, _, _)| start <= t);
        if idx == 0 {
            return 0.0;
        }
        let (start, dur, w) = self.segments[idx - 1];
        if t < start + dur {
            w
        } else {
            0.0 // numeric gap (should not happen with incremental starts)
        }
    }
}

/// A sampling power meter (GPM-8213 or Omegawatt, per `period_s`).
#[derive(Debug, Clone)]
pub struct Meter {
    pub period_s: f64,
    pub noise_frac: f64,
}

impl Meter {
    pub fn edge() -> Meter {
        Meter {
            period_s: super::calib::EDGE_METER_PERIOD_S,
            noise_frac: super::calib::METER_NOISE_FRAC,
        }
    }

    pub fn cloud() -> Meter {
        Meter {
            period_s: super::calib::CLOUD_METER_PERIOD_S,
            noise_frac: super::calib::METER_NOISE_FRAC,
        }
    }

    /// Sample the trace at the meter period (with a random phase offset,
    /// as a real free-running meter has) and noisy amplitude.
    pub fn sample(&self, trace: &PowerTrace, rng: &mut Pcg32) -> Vec<(f64, f64)> {
        let total = trace.total_duration();
        let phase = rng.f64() * self.period_s;
        let mut samples = Vec::new();
        // Always include the endpoints so trapezoid covers the full window.
        samples.push((0.0, self.read(trace, 0.0, rng)));
        let mut t = phase;
        while t < total {
            samples.push((t, self.read(trace, t, rng)));
            t += self.period_s;
        }
        samples.push((total, self.read(trace, total.max(0.0) - 1e-9, rng)));
        samples
    }

    fn read(&self, trace: &PowerTrace, t: f64, rng: &mut Pcg32) -> f64 {
        let p = trace.power_at(t);
        (p * (1.0 + rng.gaussian(0.0, self.noise_frac))).max(0.0)
    }

    /// Measured energy: trapezoidal integration over the samples — the
    /// paper's §6.1 methodology.
    pub fn measure_energy_j(&self, trace: &PowerTrace, rng: &mut Pcg32) -> f64 {
        stats::trapezoid(&self.sample(trace, rng))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn true_energy_sums_segments() {
        let mut t = PowerTrace::new();
        t.push(2.0, 5.0);
        t.push(1.0, 3.0);
        assert!((t.true_energy_j() - 13.0).abs() < 1e-12);
        assert!((t.total_duration() - 3.0).abs() < 1e-12);
    }

    #[test]
    fn power_at_segment_boundaries() {
        let mut t = PowerTrace::new();
        t.push(1.0, 5.0);
        t.push(1.0, 3.0);
        assert_eq!(t.power_at(0.5), 5.0);
        assert_eq!(t.power_at(1.5), 3.0);
        assert_eq!(t.power_at(99.0), 0.0);
    }

    #[test]
    fn long_trace_measures_accurately() {
        // A trial long vs the sampling period: measured ≈ true (±5%).
        let mut trace = PowerTrace::new();
        for i in 0..100 {
            trace.push(0.5, if i % 2 == 0 { 4.0 } else { 6.0 });
        }
        let meter = Meter::edge();
        let mut rng = Pcg32::seeded(1);
        let measured = meter.measure_energy_j(&trace, &mut rng);
        let truth = trace.true_energy_j();
        assert!((measured - truth).abs() / truth < 0.05, "{measured} vs {truth}");
    }

    #[test]
    fn short_trace_is_noisy_but_batching_fixes_it() {
        // One 50 ms inference vs the 200 ms edge meter: huge error possible.
        // 1,000 batched inferences: accurate.  This is the paper's §6.2.2
        // argument, reproduced quantitatively.
        let meter = Meter::edge();
        let mut one = PowerTrace::new();
        one.push(0.050, 5.0);
        let mut batch = PowerTrace::new();
        batch.push(0.050 * 1000.0, 5.0);
        let mut rng = Pcg32::seeded(2);
        let mut short_errs = Vec::new();
        let mut long_errs = Vec::new();
        for _ in 0..50 {
            let m1 = meter.measure_energy_j(&one, &mut rng);
            short_errs.push((m1 - one.true_energy_j()).abs() / one.true_energy_j());
            let mb = meter.measure_energy_j(&batch, &mut rng) / 1000.0;
            long_errs.push((mb - one.true_energy_j()).abs() / one.true_energy_j());
        }
        let short_mean = crate::util::stats::mean(&short_errs);
        let long_mean = crate::util::stats::mean(&long_errs);
        assert!(long_mean < 0.02, "batched error {long_mean}");
        assert!(short_mean > 2.0 * long_mean, "short {short_mean} vs long {long_mean}");
    }

    #[test]
    fn cloud_meter_resolves_faster_events() {
        // 20 ms sampling resolves a 200 ms event far better than the edge
        // meter resolves it.
        let mut trace = PowerTrace::new();
        trace.push(0.200, 1000.0);
        let mut rng_a = Pcg32::seeded(3);
        let mut rng_b = Pcg32::seeded(3);
        let truth = trace.true_energy_j();
        let cloud_errs: Vec<f64> = (0..40)
            .map(|_| (Meter::cloud().measure_energy_j(&trace, &mut rng_a) - truth).abs() / truth)
            .collect();
        let edge_errs: Vec<f64> = (0..40)
            .map(|_| (Meter::edge().measure_energy_j(&trace, &mut rng_b) - truth).abs() / truth)
            .collect();
        assert!(
            crate::util::stats::mean(&cloud_errs) < crate::util::stats::mean(&edge_errs)
        );
    }
}
