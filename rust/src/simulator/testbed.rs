//! Trial orchestration — what the DynaSplit Solver does per candidate
//! configuration (§4.2.3): configure the testbed, run a batch of
//! inferences, and collect (latency, energy, accuracy) through the
//! measurement chain.
//!
//! The batch execution mirrors the paper's §6.2.2 measurement mode:
//! the edge performs `n` head inferences back-to-back, streams the
//! intermediate outputs, the cloud performs `n` tail inferences — which
//! stretches the active windows far beyond the power-meter sampling
//! periods so energy readings are stable.

use super::accuracy::AccuracyTable;
use super::calib;
use super::device::DeviceModel;
use super::meter::{Meter, PowerTrace};
use super::netlink::Link;
use super::power::{cloud_power, edge_power, EdgeState};
use crate::model::NetCost;
use crate::space::{Config, Network};
use crate::util::rng::Pcg32;

/// Result of one trial (averages are per single inference).
#[derive(Debug, Clone)]
pub struct TrialResult {
    pub config: Config,
    /// Mean end-to-end latency per inference (ms).
    pub latency_ms: f64,
    /// Per-inference latencies (ms) — feeds distribution plots.
    pub latencies_ms: Vec<f64>,
    /// Measured energy per inference (J), edge + cloud.
    pub energy_j: f64,
    pub edge_energy_j: f64,
    pub cloud_energy_j: f64,
    /// Measured classification accuracy for this configuration.
    pub accuracy: f64,
    /// Mean latency decomposition (ms).
    pub edge_ms: f64,
    pub net_ms: f64,
    pub cloud_ms: f64,
}

impl TrialResult {
    /// Objective vector for the MOOP (all minimized): latency, energy,
    /// negated accuracy (§3.5).
    ///
    /// Accuracy is quantized to 0.1% — the resolution at which a
    /// 1,000-inference trial can measure it (1 flip = 0.1%).  Without
    /// this, sub-resolution accuracy jitter makes nearly every
    /// configuration non-dominated and the front balloons far past the
    /// paper's 12–15 entries.
    pub fn objectives(&self) -> [f64; 3] {
        [self.latency_ms, self.energy_j, -(self.accuracy * 1000.0).round() / 1000.0]
    }
}

/// The simulated edge-cloud testbed.  `Clone` so experiments can fork a
/// *shifted* world (degraded link, throttled edge) from a calibrated
/// base mid-run — the drift scenarios the adaptation loop closes on.
#[derive(Clone)]
pub struct Testbed {
    pub vgg: DeviceModel,
    pub vit: DeviceModel,
    pub link: Link,
    pub accuracy: AccuracyTable,
    pub edge_meter: Meter,
    pub cloud_meter: Meter,
    /// Inferences batched per trial (paper: 1,000).
    pub batch_per_trial: usize,
}

impl Testbed {
    pub fn new(accuracy: AccuracyTable) -> Testbed {
        Testbed {
            vgg: DeviceModel::new(NetCost::of(Network::Vgg16)),
            vit: DeviceModel::new(NetCost::of(Network::Vit)),
            link: Link::default(),
            accuracy,
            edge_meter: Meter::edge(),
            cloud_meter: Meter::cloud(),
            batch_per_trial: 1000,
        }
    }

    /// Simulator-only testbed (synthetic accuracy table) for tests and
    /// artifact-free solver runs.
    pub fn synthetic() -> Testbed {
        Testbed::new(AccuracyTable::synthetic())
    }

    pub fn device(&self, net: Network) -> &DeviceModel {
        match net {
            Network::Vgg16 => &self.vgg,
            Network::Vit => &self.vit,
        }
    }

    /// Per-inference jittered latency breakdown (seconds).
    fn sample_inference(
        &self,
        config: &Config,
        rng: &mut Pcg32,
    ) -> (f64, f64, f64, f64) {
        let base = self.device(config.net).latency(config);
        let mut jitter = rng.lognormal(0.0, calib::LATENCY_JITTER_SIGMA);
        // Fig. 2a: unexplained outliers at the 800 MHz step.
        if config.cpu_ghz() == 0.8 && rng.chance(calib::OUTLIER_800MHZ_P) {
            jitter *= calib::OUTLIER_800MHZ_FACTOR;
        }
        let edge = base.edge_s * jitter;
        let tpu = base.edge_tpu_s * jitter;
        let net = if base.net_s > 0.0 {
            self.link.sample_transfer_s(
                self.device(config.net).cost.transfer_bytes(config.split)
                    + self.device(config.net).cost.result_bytes(),
                rng,
            )
        } else {
            0.0
        };
        let cloud = base.cloud_s * rng.lognormal(0.0, calib::LATENCY_JITTER_SIGMA);
        (edge, tpu, net, cloud)
    }

    /// Run one trial of `batch_per_trial` inferences under `config`.
    pub fn run_trial(&self, config: &Config, rng: &mut Pcg32) -> TrialResult {
        self.run_trial_n(config, self.batch_per_trial, rng)
    }

    /// Run one trial with an explicit batch size.
    pub fn run_trial_n(&self, config: &Config, n: usize, rng: &mut Pcg32) -> TrialResult {
        assert!(n > 0);
        let mut latencies_ms = Vec::with_capacity(n);
        let (mut sum_e, mut sum_n, mut sum_c) = (0.0f64, 0.0, 0.0);
        let mut edge_trace = PowerTrace::new();
        let mut cloud_trace = PowerTrace::new();
        let mut total_tpu_s = 0.0;
        let mut total_cpu_s = 0.0;
        let mut total_cloud_s = 0.0;

        for _ in 0..n {
            let (edge, tpu, net, cloud) = self.sample_inference(config, rng);
            latencies_ms.push((edge + net + cloud) * 1000.0);
            sum_e += edge;
            sum_n += net;
            sum_c += cloud;
            total_tpu_s += tpu;
            total_cpu_s += edge - tpu;
            total_cloud_s += cloud;
        }

        // --- build the batched-execution power traces (§6.2.2) ---
        // Edge: CPU phase + TPU phase back-to-back over the n heads, then
        // idle while the batch transfers and the cloud computes the tails.
        edge_trace.push(total_cpu_s, edge_power(EdgeState::CpuBusy, config));
        edge_trace.push(total_tpu_s, edge_power(EdgeState::TpuBusy, config));
        if !config.is_edge_only() {
            let batch_transfer = self.link.rtt_s
                + (n as u64 * self.device(config.net).cost.transfer_bytes(config.split)) as f64
                    / self.link.bytes_per_s;
            edge_trace.push(batch_transfer + total_cloud_s, edge_power(EdgeState::Idle, config));
            // Cloud: active only during the tail window (§3.4).
            cloud_trace.push(total_cloud_s, cloud_power(config));
        }

        let edge_energy = self.edge_meter.measure_energy_j(&edge_trace, rng) / n as f64;
        let cloud_energy = if config.is_edge_only() {
            0.0
        } else {
            self.cloud_meter.measure_energy_j(&cloud_trace, rng) / n as f64
        };

        let inv_n = 1.0 / n as f64;
        TrialResult {
            config: *config,
            latency_ms: latencies_ms.iter().sum::<f64>() * inv_n,
            latencies_ms,
            energy_j: edge_energy + cloud_energy,
            edge_energy_j: edge_energy,
            cloud_energy_j: cloud_energy,
            accuracy: self.accuracy.sample(config, rng),
            edge_ms: sum_e * 1000.0 * inv_n,
            net_ms: sum_n * 1000.0 * inv_n,
            cloud_ms: sum_c * 1000.0 * inv_n,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::space::{feasible, Space, TpuMode};

    fn cfg(net: Network, cpu_idx: usize, tpu: TpuMode, gpu: bool, split: usize) -> Config {
        feasible::repair(Config { net, cpu_idx, tpu, gpu, split })
    }

    fn trial(config: &Config, seed: u64) -> TrialResult {
        let tb = Testbed::synthetic();
        let mut rng = Pcg32::seeded(seed);
        tb.run_trial_n(config, 300, &mut rng)
    }

    #[test]
    fn vgg_edge_baseline_matches_paper() {
        // §6.3.1/2: edge baseline (TPU max, CPU max) ≈ 425 ms, < 3 J.
        let t = trial(&cfg(Network::Vgg16, 6, TpuMode::Max, false, 22), 1);
        assert!((380.0..480.0).contains(&t.latency_ms), "{}", t.latency_ms);
        assert!(t.energy_j < 3.0, "{}", t.energy_j);
        assert_eq!(t.cloud_energy_j, 0.0);
    }

    #[test]
    fn vgg_cloud_baseline_matches_paper() {
        // §6.3.1/2: cloud baseline ≈ 96 ms, ≈ 68 J.
        let t = trial(&cfg(Network::Vgg16, 6, TpuMode::Off, true, 0), 2);
        assert!((85.0..115.0).contains(&t.latency_ms), "{}", t.latency_ms);
        assert!((45.0..95.0).contains(&t.energy_j), "{}", t.energy_j);
    }

    #[test]
    fn vit_baselines_match_paper() {
        // edge ≈ 3,926 ms / ≈ 16-18 J ; cloud ≈ 117 ms / ≈ 90 J.
        let e = trial(&cfg(Network::Vit, 6, TpuMode::Off, false, 19), 3);
        assert!((3500.0..4400.0).contains(&e.latency_ms), "{}", e.latency_ms);
        assert!((12.0..24.0).contains(&e.energy_j), "{}", e.energy_j);
        let c = trial(&cfg(Network::Vit, 6, TpuMode::Off, true, 0), 4);
        assert!((105.0..140.0).contains(&c.latency_ms), "{}", c.latency_ms);
        assert!((60.0..120.0).contains(&c.energy_j), "{}", c.energy_j);
    }

    #[test]
    fn headline_energy_reduction_reachable() {
        // Abstract: up to 72% energy reduction vs cloud-only.
        let cloud = trial(&cfg(Network::Vgg16, 6, TpuMode::Off, true, 0), 5);
        let edge = trial(&cfg(Network::Vgg16, 6, TpuMode::Max, false, 22), 6);
        let reduction = 1.0 - edge.energy_j / cloud.energy_j;
        assert!(reduction > 0.72, "only {:.0}% reduction", reduction * 100.0);
    }

    #[test]
    fn latency_decomposition_consistent() {
        let t = trial(&cfg(Network::Vgg16, 4, TpuMode::Std, true, 9), 7);
        let sum = t.edge_ms + t.net_ms + t.cloud_ms;
        assert!((sum - t.latency_ms).abs() / t.latency_ms < 1e-6);
        assert_eq!(t.latencies_ms.len(), 300);
    }

    #[test]
    fn deterministic_given_seed() {
        let c = cfg(Network::Vgg16, 3, TpuMode::Off, true, 5);
        let a = trial(&c, 11);
        let b = trial(&c, 11);
        assert_eq!(a.latency_ms, b.latency_ms);
        assert_eq!(a.energy_j, b.energy_j);
    }

    #[test]
    fn outliers_at_800mhz_only() {
        let tb = Testbed::synthetic();
        let spread = |cpu_idx: usize, seed: u64| {
            let mut rng = Pcg32::seeded(seed);
            let c = cfg(Network::Vgg16, cpu_idx, TpuMode::Off, false, 22);
            let t = tb.run_trial_n(&c, 400, &mut rng);
            let s = crate::util::stats::Summary::of(&t.latencies_ms);
            (s.max - s.median) / s.median
        };
        // 0.8 GHz (idx 1) shows a heavier tail than 1.0 GHz (idx 2).
        assert!(spread(1, 12) > spread(2, 12) + 0.2);
    }

    #[test]
    fn energy_integrates_edge_idle_during_cloud_phase() {
        // §3.4: edge energy spans the whole inference window, including
        // waiting for the cloud — so a split config must charge more edge
        // energy than its head compute alone would.
        let tb = Testbed::synthetic();
        let mut rng = Pcg32::seeded(13);
        // k=0 cloud-only on slow CPU: nearly all edge energy is idle wait.
        let t = tb.run_trial_n(&cfg(Network::Vgg16, 0, TpuMode::Off, false, 0), 300, &mut rng);
        // idle power ≈ 2.7 W over ~ (prep + net + slow cloud tail)
        assert!(t.edge_energy_j > 0.5, "{}", t.edge_energy_j);
    }

    #[test]
    fn all_feasible_configs_produce_finite_results() {
        let tb = Testbed::synthetic();
        let mut rng = Pcg32::seeded(14);
        for net in Network::ALL {
            for c in Space::new(net).enumerate_feasible().iter().step_by(17) {
                let t = tb.run_trial_n(c, 10, &mut rng);
                assert!(t.latency_ms.is_finite() && t.latency_ms > 0.0, "{c:?}");
                assert!(t.energy_j.is_finite() && t.energy_j > 0.0, "{c:?}");
                assert!((0.0..=1.0).contains(&t.accuracy), "{c:?}");
            }
        }
    }
}
