//! Edge↔cloud network link model.
//!
//! T_net = RTT + bytes / bandwidth over the *actual* intermediate tensor
//! size of the chosen split point (from `model::meta`), which is what
//! makes split-point selection non-trivial: early VGG conv outputs are
//! bigger than the input image (paper finding iii), so "split early to
//! save edge compute" can lose on transfer time.

use super::calib;
use crate::util::rng::Pcg32;

/// Link parameters (defaults from calib; overridable for ablations).
#[derive(Debug, Clone, Copy)]
pub struct Link {
    pub rtt_s: f64,
    pub bytes_per_s: f64,
    /// Lognormal sigma of per-transfer jitter.
    pub jitter_sigma: f64,
}

impl Default for Link {
    fn default() -> Self {
        Link {
            rtt_s: calib::LINK_RTT_S,
            bytes_per_s: calib::LINK_BYTES_PER_S,
            jitter_sigma: 0.08,
        }
    }
}

impl Link {
    /// Deterministic transfer time for `bytes` (one round trip).
    pub fn transfer_s(&self, bytes: u64) -> f64 {
        self.rtt_s + bytes as f64 / self.bytes_per_s
    }

    /// Jittered transfer time (WAN latency variation).
    pub fn sample_transfer_s(&self, bytes: u64, rng: &mut Pcg32) -> f64 {
        self.transfer_s(bytes) * rng.lognormal(0.0, self.jitter_sigma)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rtt_floor() {
        let l = Link::default();
        assert!(l.transfer_s(0) >= l.rtt_s);
    }

    #[test]
    fn linear_in_bytes() {
        let l = Link::default();
        let d = l.transfer_s(2_000_000) - l.transfer_s(1_000_000);
        assert!((d - 1_000_000.0 / l.bytes_per_s).abs() < 1e-12);
    }

    #[test]
    fn jitter_centered() {
        let l = Link::default();
        let mut rng = Pcg32::seeded(4);
        let base = l.transfer_s(100_000);
        let n = 5_000;
        let mean: f64 =
            (0..n).map(|_| l.sample_transfer_s(100_000, &mut rng)).sum::<f64>() / n as f64;
        assert!((mean / base - 1.0).abs() < 0.03, "mean ratio {}", mean / base);
    }
}
