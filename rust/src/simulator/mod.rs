//! The edge-cloud testbed simulator (paper §6.1's physical testbed,
//! substituted per DESIGN.md §Substitutions).
//!
//! The paper measures every trial on real hardware: a Raspberry Pi 4B
//! with userspace DVFS, a Coral USB edge TPU, a Grid'5000 node with a
//! V100, a GW-Instek GPM-8213 power meter (200 ms sampling) on the edge
//! and an Omegawatt wattmeter (20 ms) on the cloud node.  We rebuild that
//! testbed as a calibrated simulator:
//!
//! * [`calib`]   — every constant, each derived from a number in the paper;
//! * [`device`]  — per-segment latency model (DVFS, TPU, GPU rates);
//! * [`power`]   — instantaneous power model for both nodes;
//! * [`meter`]   — sampling-limited power meters + trapezoidal energy
//!   integration (including *why* the paper batches 1,000 inferences);
//! * [`netlink`] — edge↔cloud link (RTT + bandwidth on real tensor sizes);
//! * [`accuracy`]— accuracy lookup (from the manifest's expected table or
//!   the PJRT-measured cache) + measurement jitter;
//! * [`testbed`] — trial orchestration: configure → run n inferences →
//!   collect (latency, energy, accuracy) like the DynaSplit Solver does.

pub mod accuracy;
pub mod calib;
pub mod device;
pub mod meter;
pub mod netlink;
pub mod power;
pub mod testbed;

pub use accuracy::AccuracyTable;
pub use testbed::{Testbed, TrialResult};
