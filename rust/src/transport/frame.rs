//! Wire format for the edge↔cloud stream.
//!
//! Layout (all little-endian):
//! ```text
//! [4: magic "DSP1"][1: kind][8: payload len][payload][4: crc32(payload)]
//! ```
//! Kinds: `Meta` (once at stream open — the gRPC "metadata sent only once
//! at the beginning of the stream" behaviour, §5), `Tensor` (length-
//! prefixed f32 batch), `Result`, `Shutdown`.

use anyhow::{bail, Result};

pub const MAGIC: [u8; 4] = *b"DSP1";

/// Upper bound on a frame payload (64 MiB — far above any activation
/// batch the runtimes produce).  A corrupted length prefix otherwise
/// masquerades as an enormous incomplete frame and the receiver waits
/// forever for bytes that never come; with the cap it errors cleanly.
pub const MAX_PAYLOAD: u64 = 64 * 1024 * 1024;

/// Frame kinds on the wire.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Kind {
    Meta = 1,
    Tensor = 2,
    Result = 3,
    Shutdown = 4,
}

impl Kind {
    fn from_u8(b: u8) -> Result<Kind> {
        Ok(match b {
            1 => Kind::Meta,
            2 => Kind::Tensor,
            3 => Kind::Result,
            4 => Kind::Shutdown,
            other => bail!("unknown frame kind {other}"),
        })
    }
}

/// A decoded frame.
#[derive(Debug, Clone, PartialEq)]
pub struct Frame {
    pub kind: Kind,
    pub payload: Vec<u8>,
}

impl Frame {
    pub fn meta(meta: &StreamMeta) -> Frame {
        Frame { kind: Kind::Meta, payload: meta.encode() }
    }

    pub fn tensor(data: &[f32]) -> Frame {
        Frame { kind: Kind::Tensor, payload: f32s_to_bytes(data) }
    }

    pub fn result(data: &[f32]) -> Frame {
        Frame { kind: Kind::Result, payload: f32s_to_bytes(data) }
    }

    pub fn shutdown() -> Frame {
        Frame { kind: Kind::Shutdown, payload: Vec::new() }
    }

    pub fn tensor_f32(&self) -> Result<Vec<f32>> {
        if self.payload.len() % 4 != 0 {
            bail!("tensor payload not a multiple of 4 bytes");
        }
        Ok(bytes_to_f32s(&self.payload))
    }

    /// Serialize with header + checksum.
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(17 + self.payload.len());
        out.extend_from_slice(&MAGIC);
        out.push(self.kind as u8);
        out.extend_from_slice(&(self.payload.len() as u64).to_le_bytes());
        out.extend_from_slice(&self.payload);
        out.extend_from_slice(&crc32(&self.payload).to_le_bytes());
        out
    }

    /// Decode one frame from the head of `buf`; returns (frame, consumed)
    /// or None if `buf` does not yet hold a complete frame.
    pub fn decode(buf: &[u8]) -> Result<Option<(Frame, usize)>> {
        if buf.len() < 13 {
            return Ok(None);
        }
        if buf[..4] != MAGIC {
            bail!("bad frame magic {:02x?}", &buf[..4]);
        }
        let kind = Kind::from_u8(buf[4])?;
        let len64 = u64::from_le_bytes(buf[5..13].try_into().unwrap());
        if len64 > MAX_PAYLOAD {
            bail!(
                "frame claims a {len64}-byte payload (cap {MAX_PAYLOAD}): \
                 corrupted length prefix"
            );
        }
        let len = len64 as usize;
        let total = 13 + len + 4;
        if buf.len() < total {
            return Ok(None);
        }
        let payload = buf[13..13 + len].to_vec();
        let want = u32::from_le_bytes(buf[13 + len..total].try_into().unwrap());
        let got = crc32(&payload);
        if want != got {
            bail!("frame checksum mismatch: {want:#x} != {got:#x}");
        }
        Ok(Some((Frame { kind, payload }, total)))
    }
}

/// Stream metadata: sent exactly once when the stream opens (§5).
#[derive(Debug, Clone, PartialEq)]
pub struct StreamMeta {
    /// Which tail network to load ("vgg16" / "vit").
    pub network: String,
    /// Split layer: the cloud executes layers k..L.
    pub split: u32,
    /// Whether the cloud should use the GPU.
    pub gpu: bool,
    /// Elements per tensor message (batch * prod(shape)).
    pub tensor_len: u64,
}

impl StreamMeta {
    pub fn encode(&self) -> Vec<u8> {
        let name = self.network.as_bytes();
        let mut out = Vec::with_capacity(name.len() + 15);
        out.push(name.len() as u8);
        out.extend_from_slice(name);
        out.extend_from_slice(&self.split.to_le_bytes());
        out.push(self.gpu as u8);
        out.extend_from_slice(&self.tensor_len.to_le_bytes());
        out
    }

    pub fn decode(buf: &[u8]) -> Result<StreamMeta> {
        if buf.is_empty() {
            bail!("empty meta payload");
        }
        let nlen = buf[0] as usize;
        if buf.len() != 1 + nlen + 4 + 1 + 8 {
            bail!("meta payload has {} bytes, expected {}", buf.len(), 1 + nlen + 13);
        }
        let network = String::from_utf8(buf[1..1 + nlen].to_vec())?;
        let split = u32::from_le_bytes(buf[1 + nlen..5 + nlen].try_into().unwrap());
        let gpu = buf[5 + nlen] != 0;
        let tensor_len = u64::from_le_bytes(buf[6 + nlen..14 + nlen].try_into().unwrap());
        Ok(StreamMeta { network, split, gpu, tensor_len })
    }
}

pub fn f32s_to_bytes(data: &[f32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(data.len() * 4);
    for x in data {
        out.extend_from_slice(&x.to_le_bytes());
    }
    out
}

pub fn bytes_to_f32s(bytes: &[u8]) -> Vec<f32> {
    bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect()
}

/// CRC-32 (IEEE 802.3), table-less bitwise variant — small and sufficient
/// for frame integrity checking.
pub fn crc32(data: &[u8]) -> u32 {
    let mut crc: u32 = 0xFFFF_FFFF;
    for &b in data {
        crc ^= b as u32;
        for _ in 0..8 {
            let mask = (crc & 1).wrapping_neg();
            crc = (crc >> 1) ^ (0xEDB8_8320 & mask);
        }
    }
    !crc
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn crc32_known_vector() {
        // "123456789" -> 0xCBF43926 (standard check value)
        assert_eq!(crc32(b"123456789"), 0xCBF43926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn frame_roundtrip() {
        let f = Frame::tensor(&[1.0, -2.5, 3.25]);
        let bytes = f.encode();
        let (g, used) = Frame::decode(&bytes).unwrap().unwrap();
        assert_eq!(used, bytes.len());
        assert_eq!(g, f);
        assert_eq!(g.tensor_f32().unwrap(), vec![1.0, -2.5, 3.25]);
    }

    #[test]
    fn partial_frame_returns_none() {
        let bytes = Frame::tensor(&[1.0; 16]).encode();
        for cut in [0, 5, 12, bytes.len() - 1] {
            assert!(Frame::decode(&bytes[..cut]).unwrap().is_none(), "cut {cut}");
        }
    }

    #[test]
    fn corrupted_payload_rejected() {
        let mut bytes = Frame::tensor(&[1.0, 2.0]).encode();
        bytes[14] ^= 0xFF;
        assert!(Frame::decode(&bytes).is_err());
    }

    #[test]
    fn bad_magic_rejected() {
        let mut bytes = Frame::shutdown().encode();
        bytes[0] = b'X';
        assert!(Frame::decode(&bytes).is_err());
    }

    #[test]
    fn flipped_checksum_bytes_rejected() {
        // Corruption hitting the *checksum field itself* (not the
        // payload) must also error cleanly.
        let clean = Frame::tensor(&[4.0, 5.0]).encode();
        for i in 0..4 {
            let mut bytes = clean.clone();
            let pos = bytes.len() - 1 - i;
            bytes[pos] ^= 0x01;
            let err = Frame::decode(&bytes).unwrap_err();
            assert!(format!("{err}").contains("checksum"), "byte {pos}: {err}");
        }
    }

    #[test]
    fn corrupted_length_prefix_errors_instead_of_waiting() {
        // Garbage in the 8-byte length field would otherwise look like a
        // gigantic incomplete frame (decode -> None forever).
        let mut bytes = Frame::tensor(&[1.0]).encode();
        for b in &mut bytes[5..13] {
            *b = 0xFF;
        }
        let err = Frame::decode(&bytes).unwrap_err();
        assert!(format!("{err}").contains("length prefix"), "{err}");
    }

    #[test]
    fn truncated_length_prefix_is_incomplete_not_panic() {
        // Fewer bytes than the fixed header: decode must report "need
        // more" (None), never slice-panic.
        let bytes = Frame::tensor(&[1.0, 2.0]).encode();
        for cut in 0..13 {
            assert!(Frame::decode(&bytes[..cut]).unwrap().is_none(), "cut {cut}");
        }
    }

    #[test]
    fn replayed_meta_header_rejected() {
        // A replayed metadata header (the same encoded meta appearing
        // twice in one payload) must fail the exact-length check, not
        // silently decode the first copy or panic on the second.
        let m = StreamMeta { network: "vgg16".into(), split: 9, gpu: true, tensor_len: 64 };
        let mut doubled = m.encode();
        doubled.extend(m.encode());
        let err = StreamMeta::decode(&doubled).unwrap_err();
        assert!(format!("{err}").contains("expected"), "{err}");
        // and the same replay arriving as a framed Meta payload
        let frame = Frame { kind: Kind::Meta, payload: doubled };
        let bytes = frame.encode();
        let (decoded, _) = Frame::decode(&bytes).unwrap().unwrap();
        assert!(StreamMeta::decode(&decoded.payload).is_err());
    }

    #[test]
    fn two_frames_in_one_buffer() {
        let mut buf = Frame::meta(&StreamMeta {
            network: "vgg16".into(),
            split: 7,
            gpu: true,
            tensor_len: 1024,
        })
        .encode();
        buf.extend(Frame::tensor(&[9.0]).encode());
        let (f1, used) = Frame::decode(&buf).unwrap().unwrap();
        assert_eq!(f1.kind, Kind::Meta);
        let (f2, used2) = Frame::decode(&buf[used..]).unwrap().unwrap();
        assert_eq!(f2.kind, Kind::Tensor);
        assert_eq!(used + used2, buf.len());
    }

    #[test]
    fn meta_roundtrip() {
        let m = StreamMeta { network: "vit".into(), split: 19, gpu: false, tensor_len: 42 };
        assert_eq!(StreamMeta::decode(&m.encode()).unwrap(), m);
    }

    #[test]
    fn meta_rejects_truncation() {
        let enc = StreamMeta {
            network: "vgg16".into(),
            split: 1,
            gpu: true,
            tensor_len: 8,
        }
        .encode();
        assert!(StreamMeta::decode(&enc[..enc.len() - 1]).is_err());
        assert!(StreamMeta::decode(&[]).is_err());
    }
}
