//! Edge-side stream session with metadata reuse.
//!
//! The paper's gRPC stream sends its metadata exactly once at stream
//! open (§5); a configuration change opens a new logical stream.  This
//! module factors that state out of the executors: a [`StreamSession`]
//! owns the transport endpoint and the last-announced [`StreamMeta`],
//! re-announcing only when the `(network, split, gpu, tensor_len)` tuple
//! changes.  Consecutive requests under the same configuration therefore
//! reuse the open stream — no metadata frame, no cloud-side
//! re-initialization — which is what the serving pipeline's config-reuse
//! cache counts as an avoided reconfiguration.

use std::time::Duration;

use anyhow::{ensure, Result};

use super::channel::Endpoint;
use super::frame::{Frame, Kind, StreamMeta};

/// One edge↔cloud stream with announce-once semantics and reuse counters.
pub struct StreamSession {
    endpoint: Endpoint,
    announced: Option<StreamMeta>,
    /// Logical streams opened (metadata frames sent).
    pub reopens: usize,
    /// Requests that reused the already-open stream.
    pub reuses: usize,
}

impl StreamSession {
    pub fn new(endpoint: Endpoint) -> StreamSession {
        StreamSession { endpoint, announced: None, reopens: 0, reuses: 0 }
    }

    /// Make `meta` the live stream: a no-op when it already is (returns
    /// `false`), otherwise announces it to the peer (returns `true`).
    pub fn ensure(&mut self, meta: &StreamMeta) -> Result<bool> {
        if self.announced.as_ref() == Some(meta) {
            self.reuses += 1;
            return Ok(false);
        }
        self.endpoint.send(&Frame::meta(meta))?;
        self.announced = Some(meta.clone());
        self.reopens += 1;
        Ok(true)
    }

    /// Send one tensor batch and wait for its result frame.
    pub fn exchange(&mut self, tensor: &[f32], timeout: Duration) -> Result<Vec<f32>> {
        ensure!(self.announced.is_some(), "exchange before any stream was announced");
        self.endpoint.send(&Frame::tensor(tensor))?;
        let frame = self.endpoint.recv(timeout)?;
        ensure!(
            frame.kind == Kind::Result,
            "protocol violation: expected Result, got {:?}",
            frame.kind
        );
        frame.tensor_f32()
    }

    /// Tell the peer to shut down (the session stays usable for stats).
    pub fn shutdown(&mut self) -> Result<()> {
        self.endpoint.send(&Frame::shutdown())?;
        self.announced = None;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::transport::channel::duplex;
    use crate::transport::cloud::{serve, TailExecutor};

    /// Adds one to every element — enough to verify plumbing.
    struct PlusOne;

    impl TailExecutor for PlusOne {
        fn execute_tail(
            &self,
            _network: &str,
            _split: usize,
            _gpu: bool,
            batch: &[f32],
        ) -> Result<Vec<f32>> {
            Ok(batch.iter().map(|x| x + 1.0).collect())
        }
    }

    const T: Duration = Duration::from_secs(2);

    fn meta(split: u32, len: u64) -> StreamMeta {
        StreamMeta { network: "vgg16".into(), split, gpu: false, tensor_len: len }
    }

    #[test]
    fn stream_reused_until_meta_changes() {
        let (edge, cloud) = duplex(None);
        let server = std::thread::spawn(move || serve(cloud, &PlusOne, T));
        let mut s = StreamSession::new(edge);

        assert!(s.ensure(&meta(3, 2)).unwrap(), "first ensure opens the stream");
        assert_eq!(s.exchange(&[1.0, 2.0], T).unwrap(), vec![2.0, 3.0]);
        // same configuration: stream is reused, no new announce
        assert!(!s.ensure(&meta(3, 2)).unwrap());
        assert_eq!(s.exchange(&[5.0, 6.0], T).unwrap(), vec![6.0, 7.0]);
        assert_eq!((s.reopens, s.reuses), (1, 1));
        // configuration change: a new logical stream
        assert!(s.ensure(&meta(7, 1)).unwrap());
        assert_eq!(s.exchange(&[0.0], T).unwrap(), vec![1.0]);
        assert_eq!((s.reopens, s.reuses), (2, 1));

        s.shutdown().unwrap();
        let stats = server.join().unwrap().unwrap();
        assert_eq!(stats.batches, 3);
    }

    #[test]
    fn exchange_without_announce_fails_fast() {
        let (edge, _cloud) = duplex(None);
        let mut s = StreamSession::new(edge);
        let err = s.exchange(&[1.0], T).unwrap_err();
        assert!(format!("{err}").contains("before any stream"));
    }

    #[test]
    fn shutdown_resets_announce_state() {
        let (edge, cloud) = duplex(None);
        let server = std::thread::spawn(move || serve(cloud, &PlusOne, T));
        let mut s = StreamSession::new(edge);
        s.ensure(&meta(3, 1)).unwrap();
        s.exchange(&[1.0], T).unwrap();
        s.shutdown().unwrap();
        server.join().unwrap().unwrap();
        assert!(s.exchange(&[1.0], T).is_err(), "stream gone after shutdown");
    }
}
