//! In-process duplex byte stream with link shaping.
//!
//! `duplex()` returns two [`Endpoint`]s connected like a TCP socket pair;
//! writes on one side become reads on the other, in order.  An optional
//! [`LinkShaping`] delays delivery to model the WAN link (RTT/2 one-way
//! latency + serialization time at the link bandwidth), so the online
//! phase's measured `T_net` comes from the same link model the simulator
//! uses.

use std::collections::VecDeque;
use std::fmt;
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender};
use std::time::Duration;

use anyhow::{Context, Result};

use super::frame::Frame;
use crate::serve::clock::WallDeadline;

/// Structured transport failure classes.  Recovery logic (the serving
/// retry loop and the per-link circuit breaker, DESIGN.md §15) must
/// classify failures without string matching, so every error the
/// endpoint produces carries one of these as its typed root — reachable
/// through any context layers via `anyhow::Error::downcast_ref`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TransportError {
    /// No complete frame arrived within the receive deadline.
    Timeout { after: Duration },
    /// The peer endpoint was dropped (stream closed, possibly
    /// mid-frame).
    Disconnected,
    /// The byte stream held a frame that failed checksum/shape
    /// validation.
    CorruptFrame,
}

impl fmt::Display for TransportError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            TransportError::Timeout { after } => {
                write!(f, "transport recv timeout after {after:?}")
            }
            TransportError::Disconnected => write!(f, "transport peer disconnected"),
            TransportError::CorruptFrame => write!(f, "transport frame corrupt"),
        }
    }
}

impl std::error::Error for TransportError {}

/// Link shaping parameters (None = loopback, no delay).
#[derive(Debug, Clone, Copy)]
pub struct LinkShaping {
    pub one_way_latency: Duration,
    pub bytes_per_s: f64,
}

impl LinkShaping {
    pub fn from_calib() -> LinkShaping {
        LinkShaping {
            one_way_latency: Duration::from_secs_f64(crate::simulator::calib::LINK_RTT_S / 2.0),
            bytes_per_s: crate::simulator::calib::LINK_BYTES_PER_S,
        }
    }

    fn delivery_delay(&self, bytes: usize) -> Duration {
        self.one_way_latency + Duration::from_secs_f64(bytes as f64 / self.bytes_per_s)
    }
}

struct Packet {
    deliver_at: WallDeadline,
    bytes: Vec<u8>,
}

/// One side of the duplex stream.
pub struct Endpoint {
    tx: Sender<Packet>,
    rx: Receiver<Packet>,
    shaping: Option<LinkShaping>,
    /// Reassembly buffer for frame decoding.
    inbox: VecDeque<u8>,
    closed: bool,
}

/// Create a connected endpoint pair with optional link shaping.
pub fn duplex(shaping: Option<LinkShaping>) -> (Endpoint, Endpoint) {
    let (tx_a, rx_b) = mpsc::channel();
    let (tx_b, rx_a) = mpsc::channel();
    (
        Endpoint { tx: tx_a, rx: rx_a, shaping, inbox: VecDeque::new(), closed: false },
        Endpoint { tx: tx_b, rx: rx_b, shaping, inbox: VecDeque::new(), closed: false },
    )
}

impl Endpoint {
    /// Send a frame (returns the modeled wire delay applied to it).
    pub fn send(&self, frame: &Frame) -> Result<Duration> {
        let bytes = frame.encode();
        let delay = self
            .shaping
            .map(|s| s.delivery_delay(bytes.len()))
            .unwrap_or(Duration::ZERO);
        let packet = Packet { deliver_at: WallDeadline::after(delay), bytes };
        if self.tx.send(packet).is_err() {
            return Err(anyhow::Error::new(TransportError::Disconnected))
                .context("peer endpoint dropped");
        }
        Ok(delay)
    }

    /// Blocking receive of the next frame, honoring shaped delivery
    /// times.  Every failure carries a typed [`TransportError`] root so
    /// retry/breaker logic classifies it without string matching.
    pub fn recv(&mut self, timeout: Duration) -> Result<Frame> {
        let deadline = WallDeadline::after(timeout);
        loop {
            // try to decode from the reassembly buffer first
            self.inbox.make_contiguous();
            let decoded = match Frame::decode(self.inbox.as_slices().0) {
                Ok(d) => d,
                Err(err) => {
                    return Err(anyhow::Error::new(TransportError::CorruptFrame))
                        .with_context(|| format!("{err:#}"));
                }
            };
            if let Some((frame, used)) = decoded {
                self.inbox.drain(..used);
                return Ok(frame);
            }
            if self.closed {
                return Err(anyhow::Error::new(TransportError::Disconnected))
                    .context("stream closed mid-frame");
            }
            let Some(remaining) = deadline.remaining() else {
                return Err(TransportError::Timeout { after: timeout }.into());
            };
            match self.rx.recv_timeout(remaining) {
                Ok(packet) => {
                    // honor the shaped delivery time
                    packet.deliver_at.sleep_until();
                    self.inbox.extend(packet.bytes);
                }
                Err(RecvTimeoutError::Timeout) => {
                    return Err(TransportError::Timeout { after: timeout }.into());
                }
                Err(RecvTimeoutError::Disconnected) => {
                    self.closed = true;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::transport::frame::{Kind, StreamMeta};

    const T: Duration = Duration::from_secs(2);

    #[test]
    fn loopback_roundtrip() {
        let (a, mut b) = duplex(None);
        a.send(&Frame::tensor(&[1.0, 2.0])).unwrap();
        let f = b.recv(T).unwrap();
        assert_eq!(f.tensor_f32().unwrap(), vec![1.0, 2.0]);
    }

    #[test]
    fn bidirectional() {
        let (mut a, mut b) = duplex(None);
        a.send(&Frame::meta(&StreamMeta {
            network: "vgg16".into(),
            split: 3,
            gpu: true,
            tensor_len: 8,
        }))
        .unwrap();
        assert_eq!(b.recv(T).unwrap().kind, Kind::Meta);
        b.send(&Frame::result(&[0.5])).unwrap();
        assert_eq!(a.recv(T).unwrap().kind, Kind::Result);
    }

    #[test]
    fn ordering_preserved() {
        let (a, mut b) = duplex(None);
        for i in 0..50 {
            a.send(&Frame::tensor(&[i as f32])).unwrap();
        }
        for i in 0..50 {
            assert_eq!(b.recv(T).unwrap().tensor_f32().unwrap(), vec![i as f32]);
        }
    }

    #[test]
    fn shaping_delays_delivery() {
        let shaping = LinkShaping {
            one_way_latency: Duration::from_millis(20),
            bytes_per_s: 1e9,
        };
        let (a, mut b) = duplex(Some(shaping));
        let sw = crate::serve::clock::Stopwatch::start();
        a.send(&Frame::tensor(&[1.0])).unwrap();
        b.recv(T).unwrap();
        assert!(sw.elapsed() >= Duration::from_millis(18), "{:?}", sw.elapsed());
    }

    #[test]
    fn bandwidth_term_scales_with_size() {
        let shaping = LinkShaping {
            one_way_latency: Duration::ZERO,
            bytes_per_s: 1e6, // 1 MB/s: 100 KB ≈ 100 ms
        };
        let (a, mut b) = duplex(Some(shaping));
        let big = vec![0f32; 25_000]; // 100 KB
        let sw = crate::serve::clock::Stopwatch::start();
        a.send(&Frame::tensor(&big)).unwrap();
        b.recv(T).unwrap();
        assert!(sw.elapsed() >= Duration::from_millis(80), "{:?}", sw.elapsed());
    }

    #[test]
    fn recv_times_out() {
        let (_a, mut b) = duplex(None);
        let err = b.recv(Duration::from_millis(30)).unwrap_err();
        assert!(format!("{err}").contains("timeout"));
        // structured kind, no string matching needed
        assert_eq!(
            err.downcast_ref::<TransportError>(),
            Some(&TransportError::Timeout { after: Duration::from_millis(30) })
        );
    }

    #[test]
    fn dropped_peer_detected() {
        let (a, mut b) = duplex(None);
        drop(a);
        let err = b.recv(Duration::from_millis(50)).unwrap_err();
        assert_eq!(err.downcast_ref::<TransportError>(), Some(&TransportError::Disconnected));
    }

    #[test]
    fn send_to_dropped_peer_is_a_disconnect() {
        let (a, b) = duplex(None);
        drop(b);
        let err = a.send(&Frame::tensor(&[1.0])).unwrap_err();
        assert_eq!(err.downcast_ref::<TransportError>(), Some(&TransportError::Disconnected));
        assert!(format!("{err}").contains("peer endpoint dropped"));
    }

    #[test]
    fn corrupt_stream_is_classified_not_stringly_typed() {
        // the Endpoint API only sends valid frames, so splice the
        // corruption in at the reassembly buffer: flip a byte so the
        // frame checksum fails — the decode error must surface as a
        // typed CorruptFrame, not a bare string
        let (_a, mut b) = duplex(None);
        let mut bytes = Frame::tensor(&[1.0, 2.0]).encode();
        let n = bytes.len();
        bytes[n - 1] ^= 0xff;
        b.inbox.extend(bytes);
        let err = b.recv(Duration::from_millis(30)).unwrap_err();
        assert_eq!(err.downcast_ref::<TransportError>(), Some(&TransportError::CorruptFrame));
    }

    #[test]
    fn works_across_threads() {
        let (a, mut b) = duplex(None);
        let h = std::thread::spawn(move || {
            for i in 0..10 {
                a.send(&Frame::tensor(&[i as f32])).unwrap();
            }
        });
        let mut sum = 0.0;
        for _ in 0..10 {
            sum += b.recv(T).unwrap().tensor_f32().unwrap()[0];
        }
        h.join().unwrap();
        assert_eq!(sum, 45.0);
    }
}
