//! Cloud-side service loop.
//!
//! Mirrors the paper's cloud node behaviour (§4.3.2-4.3.3): on stream
//! open it receives an initialization message naming the tail network,
//! the split point, and whether to use the GPU; it then serves tensor
//! batches until shutdown, streaming results back.  The actual tail
//! computation is abstracted behind [`TailExecutor`] so the service loop
//! can run over the PJRT runtime (production) or a mock (tests).

use std::time::Duration;

use anyhow::{bail, Context, Result};

use super::channel::Endpoint;
use super::frame::{Frame, Kind, StreamMeta};

/// Executes the tail segment (layers k..L) of a network on a batch.
///
/// Deliberately NOT `Send`: PJRT executables hold thread-local handles
/// (`Rc` internals in the `xla` crate), so each node thread constructs
/// its *own* executor — which is also the honest topology: the paper's
/// cloud node has its own runtime, it does not share the edge's.
pub trait TailExecutor {
    fn execute_tail(&self, network: &str, split: usize, gpu: bool, batch: &[f32])
        -> Result<Vec<f32>>;
}

/// Statistics returned when the service loop exits.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ServeStats {
    pub batches: usize,
    pub tensor_elements: usize,
}

/// Run the cloud service loop until `Shutdown` (or peer drop).
///
/// Protocol: exactly one `Meta` frame first (gRPC metadata-once), then
/// any number of `Tensor` frames each answered with a `Result` frame.
pub fn serve<E: TailExecutor>(
    mut endpoint: Endpoint,
    executor: &E,
    timeout: Duration,
) -> Result<ServeStats> {
    let first = endpoint.recv(timeout).context("waiting for stream metadata")?;
    let mut stats = ServeStats::default();
    if first.kind == Kind::Shutdown {
        // shutdown before any stream opened (e.g. the whole workload ran
        // edge-only and never touched the cloud): clean no-op exit.
        return Ok(stats);
    }
    if first.kind != Kind::Meta {
        bail!("protocol violation: first frame was {:?}, expected Meta", first.kind);
    }
    let mut meta = StreamMeta::decode(&first.payload)?;
    loop {
        let frame = match endpoint.recv(timeout) {
            Ok(f) => f,
            // peer dropping the stream is a normal end-of-request-cycle
            Err(_) if stats.batches > 0 => return Ok(stats),
            Err(e) => return Err(e),
        };
        match frame.kind {
            Kind::Shutdown => return Ok(stats),
            // a new Meta re-initializes the stream (the controller opened
            // a new logical gRPC stream after a configuration change)
            Kind::Meta => {
                meta = StreamMeta::decode(&frame.payload)?;
            }
            Kind::Tensor => {
                let batch = frame.tensor_f32()?;
                if batch.len() as u64 != meta.tensor_len {
                    bail!(
                        "tensor has {} elements, stream metadata promised {}",
                        batch.len(),
                        meta.tensor_len
                    );
                }
                let result = executor.execute_tail(
                    &meta.network,
                    meta.split as usize,
                    meta.gpu,
                    &batch,
                )?;
                endpoint.send(&Frame::result(&result))?;
                stats.batches += 1;
                stats.tensor_elements += batch.len();
            }
            other => bail!("protocol violation: unexpected {:?} mid-stream", other),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::transport::channel::duplex;

    /// Doubles every element — enough to verify plumbing.
    struct MockExecutor;

    impl TailExecutor for MockExecutor {
        fn execute_tail(
            &self,
            network: &str,
            split: usize,
            _gpu: bool,
            batch: &[f32],
        ) -> Result<Vec<f32>> {
            assert_eq!(network, "vgg16");
            assert_eq!(split, 7);
            Ok(batch.iter().map(|x| x * 2.0).collect())
        }
    }

    const T: Duration = Duration::from_secs(2);

    fn meta(len: u64) -> StreamMeta {
        StreamMeta { network: "vgg16".into(), split: 7, gpu: true, tensor_len: len }
    }

    #[test]
    fn serves_batches_then_shutdown() {
        let (edge, cloud) = duplex(None);
        let server = std::thread::spawn(move || serve(cloud, &MockExecutor, T));
        let mut edge = edge;
        edge.send(&Frame::meta(&meta(3))).unwrap();
        for i in 0..5 {
            edge.send(&Frame::tensor(&[i as f32, 1.0, 2.0])).unwrap();
            let r = edge.recv(T).unwrap();
            assert_eq!(r.kind, Kind::Result);
            assert_eq!(r.tensor_f32().unwrap(), vec![i as f32 * 2.0, 2.0, 4.0]);
        }
        edge.send(&Frame::shutdown()).unwrap();
        let stats = server.join().unwrap().unwrap();
        assert_eq!(stats.batches, 5);
        assert_eq!(stats.tensor_elements, 15);
    }

    #[test]
    fn rejects_tensor_before_meta() {
        let (edge, cloud) = duplex(None);
        let server = std::thread::spawn(move || serve(cloud, &MockExecutor, T));
        edge.send(&Frame::tensor(&[1.0])).unwrap();
        let err = server.join().unwrap().unwrap_err();
        assert!(format!("{err}").contains("protocol violation"));
    }

    #[test]
    fn rejects_wrong_tensor_length() {
        let (edge, cloud) = duplex(None);
        let server = std::thread::spawn(move || serve(cloud, &MockExecutor, T));
        let mut edge = edge;
        edge.send(&Frame::meta(&meta(3))).unwrap();
        edge.send(&Frame::tensor(&[1.0])).unwrap(); // promised 3, sent 1
        let err = server.join().unwrap().unwrap_err();
        assert!(format!("{err}").contains("promised"));
    }

    #[test]
    fn peer_drop_after_batches_is_clean_end() {
        let (edge, cloud) = duplex(None);
        let server = std::thread::spawn(move || serve(cloud, &MockExecutor, T));
        let mut edge = edge;
        edge.send(&Frame::meta(&meta(1))).unwrap();
        edge.send(&Frame::tensor(&[5.0])).unwrap();
        edge.recv(T).unwrap();
        drop(edge);
        let stats = server.join().unwrap().unwrap();
        assert_eq!(stats.batches, 1);
    }
}
