//! Edge↔cloud bidirectional streaming transport (gRPC substitute).
//!
//! The paper connects the edge and cloud nodes with gRPC bidirectional
//! streams: stream metadata is sent once at stream-open, then tensors
//! flow continuously and intermediate buffers are released progressively
//! (§5).  We reproduce those semantics over std threads + channels:
//!
//! * [`frame`]  — wire format: framed messages with a one-time metadata
//!   header, length-prefixed tensor payloads, checksums;
//! * [`channel`]— in-process duplex byte-stream with an injectable link
//!   model (latency + bandwidth) so transfer time behaves like the WAN
//!   link of the testbed;
//! * [`cloud`]  — the cloud-side service loop: receives an init message
//!   (which tail network, GPU on/off), then serves tensor batches;
//! * [`session`]— edge-side announce-once stream state, so consecutive
//!   requests under one configuration reuse the open stream.
//!
//! The transport moves *real tensor bytes* (the PJRT head outputs) — it
//! is on the request path, python is not.
//!
//! Failure handling is deliberately loud: frames carry checksums and a
//! 64 MiB length cap, and the decode path is hardened against flipped
//! checksum bytes, truncated length prefixes, and replayed metadata
//! headers (see the `frame` tests).  One [`StreamSession`] per
//! `(worker, configuration)` announces metadata exactly once and is
//! reused across requests — the transport-level analogue of the serving
//! pipeline's config-reuse cache.

pub mod channel;
pub mod cloud;
pub mod frame;
pub mod session;

pub use channel::{duplex, Endpoint, LinkShaping, TransportError};
pub use frame::{Frame, StreamMeta};
pub use session::StreamSession;
