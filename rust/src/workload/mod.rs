//! Workload generation (§6.2.1) and open-loop timed workloads.
//!
//! Each user request asks for an inference task (a batch of images) with
//! a QoS level — the maximum acceptable inference latency.  The paper
//! draws QoS levels from a Weibull distribution with shape 1 (i.e.
//! exponential), "a well-known distribution that models real-world
//! latency distribution" [2], and rescales the samples so the smallest
//! equals the minimum observed latency and the largest the maximum
//! observed latency for the network (Table 2).
//!
//! Layering:
//!
//! * [`WorkloadGen`] — per-network QoS draws ([`Request`]s);
//! * [`arrival`] — arrival processes (Poisson / bursty / trace) stamping
//!   requests into an open-loop [`TimedRequest`] timeline;
//! * [`mix`] — mixed-network workloads: one timeline interleaving
//!   several networks per a [`NetworkMix`] (`--mix vgg16=0.7,vit=0.3`),
//!   each request's QoS drawn from its own network's bounds;
//! * [`fleet`] — fleet-scale workloads: weighted heterogeneous device
//!   classes under diurnal + flash-crowd arrival traces (`dynasplit
//!   scale`).

pub mod arrival;
pub mod fleet;
pub mod mix;

use crate::space::Network;
use crate::util::rng::Pcg32;

pub use arrival::{timeline, ArrivalProcess, TimedRequest};
pub use fleet::{DeviceClass, FleetSpec};
pub use mix::{mixed_timeline, NetworkMix};

/// Latency bounds used to scale QoS draws (Table 2 defaults; solver runs
/// can substitute their own measured bounds).
#[derive(Debug, Clone, Copy)]
pub struct LatencyBounds {
    pub min_ms: f64,
    pub max_ms: f64,
}

impl LatencyBounds {
    /// Paper Table 2 values.
    pub fn paper(net: Network) -> LatencyBounds {
        match net {
            Network::Vgg16 => LatencyBounds { min_ms: 90.6, max_ms: 5026.8 },
            Network::Vit => LatencyBounds { min_ms: 118.8, max_ms: 10_287.6 },
        }
    }
}

/// One user request.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: usize,
    pub net: Network,
    /// QoS level: maximum acceptable inference latency (ms).
    pub qos_ms: f64,
    /// Inferences in the request (paper: 1,000 images per request).
    pub inferences: usize,
    /// Per-request RNG seed (controller noise, data sampling).
    pub seed: u64,
}

/// Workload generator: Weibull(shape=1) QoS draws min-max-rescaled to the
/// network's observed latency bounds.
#[derive(Debug, Clone)]
pub struct WorkloadGen {
    pub net: Network,
    pub bounds: LatencyBounds,
    pub inferences_per_request: usize,
}

impl WorkloadGen {
    pub fn new(net: Network, bounds: LatencyBounds) -> WorkloadGen {
        WorkloadGen { net, bounds, inferences_per_request: 1000 }
    }

    pub fn paper(net: Network) -> WorkloadGen {
        WorkloadGen::new(net, LatencyBounds::paper(net))
    }

    /// Generate `n` requests.  The raw Weibull(1, 1) draws are rescaled so
    /// min→bounds.min and max→bounds.max (the paper's construction,
    /// §6.2.1), making the QoS spectrum span exactly the feasible range.
    pub fn generate(&self, n: usize, rng: &mut Pcg32) -> Vec<Request> {
        assert!(n >= 2, "need at least 2 requests to span the bounds");
        let raw: Vec<f64> = (0..n).map(|_| rng.weibull(1.0, 1.0)).collect();
        let lo = raw.iter().cloned().fold(f64::INFINITY, f64::min);
        let hi = raw.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let span = (hi - lo).max(1e-12);
        raw.iter()
            .enumerate()
            .map(|(id, &x)| Request {
                id,
                net: self.net,
                qos_ms: self.bounds.min_ms
                    + (x - lo) / span * (self.bounds.max_ms - self.bounds.min_ms),
                inferences: self.inferences_per_request,
                seed: rng.next_u64(),
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop::{forall, Config as PropConfig};
    use crate::util::stats;

    #[test]
    fn qos_spans_bounds_exactly() {
        let gen = WorkloadGen::paper(Network::Vgg16);
        let mut rng = Pcg32::seeded(1);
        let reqs = gen.generate(100, &mut rng);
        let qos: Vec<f64> = reqs.iter().map(|r| r.qos_ms).collect();
        let lo = qos.iter().cloned().fold(f64::INFINITY, f64::min);
        let hi = qos.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        assert!((lo - 90.6).abs() < 1e-9);
        assert!((hi - 5026.8).abs() < 1e-9);
    }

    #[test]
    fn vit_qos_spans_table2_bounds_exactly() {
        // Table 2, ViT row: 118.8 ms .. 10,287.6 ms — the rescale must
        // pin the extremes of every draw set to exactly these values.
        let gen = WorkloadGen::paper(Network::Vit);
        let mut rng = Pcg32::seeded(21);
        let reqs = gen.generate(64, &mut rng);
        let qos: Vec<f64> = reqs.iter().map(|r| r.qos_ms).collect();
        let lo = qos.iter().cloned().fold(f64::INFINITY, f64::min);
        let hi = qos.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        assert!((lo - 118.8).abs() < 1e-9, "min {lo}");
        assert!((hi - 10_287.6).abs() < 1e-9, "max {hi}");
        assert!(qos.iter().all(|&q| (118.8 - 1e-9..=10_287.6 + 1e-9).contains(&q)));
    }

    #[test]
    fn minimal_two_request_workload_hits_both_bounds() {
        // n = 2 is the degenerate rescale: one draw becomes the Table-2
        // minimum, the other the maximum, regardless of the raw values.
        for net in Network::ALL {
            let b = LatencyBounds::paper(net);
            let reqs = WorkloadGen::paper(net).generate(2, &mut Pcg32::seeded(5));
            let mut qos = [reqs[0].qos_ms, reqs[1].qos_ms];
            qos.sort_by(f64::total_cmp);
            assert!((qos[0] - b.min_ms).abs() < 1e-9, "{net:?} min {}", qos[0]);
            assert!((qos[1] - b.max_ms).abs() < 1e-9, "{net:?} max {}", qos[1]);
        }
    }

    #[test]
    fn rescale_preserves_draw_order() {
        // generate() draws all raw Weibull samples *first*, then the
        // per-request seeds, so replaying the same RNG stream recovers
        // the raw draws.  The rescale is affine with positive slope:
        // request QoS ranks must equal raw draw ranks.
        let gen = WorkloadGen::paper(Network::Vgg16);
        let n = 40;
        let mut replay = Pcg32::seeded(31);
        let raw: Vec<f64> = (0..n).map(|_| replay.weibull(1.0, 1.0)).collect();
        let reqs = gen.generate(n, &mut Pcg32::seeded(31));
        for (a, b) in (0..n).zip(1..n) {
            let raw_ord = raw[a].total_cmp(&raw[b]);
            let qos_ord = reqs[a].qos_ms.total_cmp(&reqs[b].qos_ms);
            assert_eq!(raw_ord, qos_ord, "rank flipped between draws {a} and {b}");
        }
        // and the extremes are attained exactly once each (continuous draws)
        let b = LatencyBounds::paper(Network::Vgg16);
        let at_min = reqs.iter().filter(|r| (r.qos_ms - b.min_ms).abs() < 1e-9).count();
        let at_max = reqs.iter().filter(|r| (r.qos_ms - b.max_ms).abs() < 1e-9).count();
        assert_eq!((at_min, at_max), (1, 1));
    }

    #[test]
    fn custom_bounds_are_respected() {
        // Solver-measured bounds substitute for Table 2 (§6.2.1).
        let bounds = LatencyBounds { min_ms: 10.0, max_ms: 20.0 };
        let gen = WorkloadGen::new(Network::Vit, bounds);
        let reqs = gen.generate(50, &mut Pcg32::seeded(9));
        let qos: Vec<f64> = reqs.iter().map(|r| r.qos_ms).collect();
        let lo = qos.iter().cloned().fold(f64::INFINITY, f64::min);
        let hi = qos.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        assert!((lo - 10.0).abs() < 1e-9 && (hi - 20.0).abs() < 1e-9);
    }

    #[test]
    fn distribution_is_right_skewed() {
        // Exponential QoS ⇒ most requests demand low latency (Fig. 5):
        // median well below the midpoint of the range.
        let gen = WorkloadGen::paper(Network::Vit);
        let mut rng = Pcg32::seeded(2);
        let reqs = gen.generate(10_000, &mut rng);
        let qos: Vec<f64> = reqs.iter().map(|r| r.qos_ms).collect();
        let med = stats::median(&qos);
        let mid = (118.8 + 10_287.6) / 2.0;
        assert!(med < mid * 0.5, "median {med} vs midpoint {mid}");
    }

    #[test]
    fn deterministic_and_seed_sensitive() {
        let gen = WorkloadGen::paper(Network::Vgg16);
        let a = gen.generate(50, &mut Pcg32::seeded(3));
        let b = gen.generate(50, &mut Pcg32::seeded(3));
        let c = gen.generate(50, &mut Pcg32::seeded(4));
        assert_eq!(
            a.iter().map(|r| r.qos_ms).collect::<Vec<_>>(),
            b.iter().map(|r| r.qos_ms).collect::<Vec<_>>()
        );
        assert_ne!(
            a.iter().map(|r| r.qos_ms).collect::<Vec<_>>(),
            c.iter().map(|r| r.qos_ms).collect::<Vec<_>>()
        );
    }

    #[test]
    fn request_fields_sane() {
        forall("request fields", PropConfig::default(), |rng| {
            let gen = WorkloadGen::paper(Network::Vgg16);
            let n = 2 + rng.below(200) as usize;
            let reqs = gen.generate(n, rng);
            anyhow::ensure!(reqs.len() == n);
            for (i, r) in reqs.iter().enumerate() {
                anyhow::ensure!(r.id == i);
                anyhow::ensure!(r.qos_ms >= 90.6 - 1e-9 && r.qos_ms <= 5026.8 + 1e-9);
                anyhow::ensure!(r.inferences == 1000);
            }
            Ok(())
        });
    }
}
