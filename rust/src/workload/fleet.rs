//! Fleet-scale workloads: heterogeneous edge-device populations under
//! diurnal + flash-crowd arrival traces (ROADMAP north star; the
//! multi-tier deployment setting of PAPERS.md, arxiv 2404.08060).
//!
//! The paper's workload model is one device class at one steady rate;
//! a fleet of thousands of edge devices is neither.  A [`FleetSpec`]
//! describes the population as weighted [`DeviceClass`]es (each a
//! relative edge speed + QoS-budget scale — a throttled Jetson asks
//! looser deadlines than a reference board) and the traffic as a
//! nonhomogeneous Poisson process: a sinusoidal diurnal rate sampled
//! by *thinning* (draw candidates at the peak rate, accept with
//! probability `rate(t) / peak`), merged with deterministic
//! flash-crowd bursts every `flash_every_s` seconds.
//!
//! The device class rides inside the request's own `seed` field so the
//! `Request` struct (and everything downstream of it) stays untouched:
//! `seed = noise * K + class` for a fleet of `K` classes, recovered by
//! [`FleetSpec::class_of`].  The scale experiment maps each class to a
//! [`crate::simulator::DeviceModel`]-throttled testbed, so one pipeline
//! run serves the whole heterogeneous population.

use super::{timeline, ArrivalProcess, TimedRequest, WorkloadGen};
use crate::space::Network;
use crate::util::rng::Pcg32;

/// One class of edge devices in the fleet.
#[derive(Debug, Clone)]
pub struct DeviceClass {
    pub name: &'static str,
    /// Relative share of the fleet (normalized over all classes).
    pub weight: f64,
    /// Edge-speed factor vs the reference testbed (1.0 = the paper's
    /// hardware; 0.5 = a half-speed edge board).  Consumed by the scale
    /// experiment via [`crate::simulator::DeviceModel::throttle_edge`].
    pub edge_speed: f64,
    /// QoS budgets scale by this: slower devices negotiate looser
    /// deadlines, keeping the per-class workload satisfiable.
    pub qos_scale: f64,
}

/// A heterogeneous fleet plus its arrival trace shape.
#[derive(Debug, Clone)]
pub struct FleetSpec {
    pub net: Network,
    /// Device classes; must be non-empty with positive weights.
    pub classes: Vec<DeviceClass>,
    /// Simulated devices in the fleet (each request is pinned to one
    /// via [`FleetSpec::device_of`]).
    pub devices: usize,
    /// Mean aggregate arrival rate over the whole trace (req/s).
    pub mean_rate_per_s: f64,
    /// Diurnal modulation depth in `[0, 1)`:
    /// `rate(t) = mean · (1 + depth · sin(2πt / period))`.
    pub diurnal_depth: f64,
    /// Diurnal period (s).
    pub period_s: f64,
    /// A flash crowd of `flash_size` back-to-back arrivals fires every
    /// `flash_every_s` seconds (0 size disables them).
    pub flash_every_s: f64,
    pub flash_size: usize,
    /// Inferences per request (the scale experiment uses small values;
    /// the paper's batch is 1000).
    pub inferences_per_request: usize,
}

impl FleetSpec {
    /// A three-class synthetic fleet: reference boards, throttled
    /// mid-tier devices, and slow low-power stragglers, under a
    /// 60-second diurnal cycle with periodic flash crowds.
    pub fn synthetic(net: Network, devices: usize, mean_rate_per_s: f64) -> FleetSpec {
        FleetSpec {
            net,
            classes: vec![
                DeviceClass { name: "reference", weight: 0.5, edge_speed: 1.0, qos_scale: 1.0 },
                DeviceClass { name: "throttled", weight: 0.3, edge_speed: 0.6, qos_scale: 1.5 },
                DeviceClass { name: "low-power", weight: 0.2, edge_speed: 0.35, qos_scale: 2.5 },
            ],
            devices: devices.max(1),
            mean_rate_per_s,
            diurnal_depth: 0.6,
            period_s: 60.0,
            flash_every_s: 20.0,
            flash_size: 64,
            inferences_per_request: 1,
        }
    }

    /// Number of device classes (the `K` of the seed encoding).
    pub fn class_count(&self) -> usize {
        self.classes.len()
    }

    /// Recover the device class encoded in a request seed.
    pub fn class_of(&self, seed: u64) -> usize {
        (seed % self.classes.len() as u64) as usize
    }

    /// Stable simulated-device id for a request seed (uniform over the
    /// fleet — the class encoding occupies the low bits, the device
    /// draw the rest).
    pub fn device_of(&self, seed: u64) -> usize {
        ((seed / self.classes.len() as u64) % self.devices as u64) as usize
    }

    /// Draw `n` nondecreasing arrival offsets (ms) from the diurnal
    /// process by thinning, then merge the deterministic flash crowds
    /// that land inside the base horizon (exactly `n` offsets total,
    /// like [`ArrivalProcess::Bursty`]).
    pub fn arrival_times_ms(&self, n: usize, rng: &mut Pcg32) -> Vec<f64> {
        assert!(self.mean_rate_per_s > 0.0, "fleet rate must be positive");
        assert!(
            (0.0..1.0).contains(&self.diurnal_depth),
            "diurnal depth must be in [0, 1)"
        );
        assert!(self.period_s > 0.0, "diurnal period must be positive");
        if n == 0 {
            return Vec::new();
        }
        // thinning: candidates at the peak rate, accepted with
        // probability rate(t) / peak — the standard exact sampler for a
        // nonhomogeneous Poisson process
        let peak = self.mean_rate_per_s * (1.0 + self.diurnal_depth);
        let mean_gap_ms = 1000.0 / peak;
        let omega = 2.0 * std::f64::consts::PI / (self.period_s * 1000.0);
        let mut base = Vec::with_capacity(n);
        let mut t = 0.0;
        while base.len() < n {
            t += rng.weibull(1.0, mean_gap_ms);
            let rate = self.mean_rate_per_s * (1.0 + self.diurnal_depth * (omega * t).sin());
            if rng.chance(rate / peak) {
                base.push(t);
            }
        }
        if self.flash_size == 0 {
            return base;
        }
        assert!(self.flash_every_s > 0.0, "flash period must be positive");
        let horizon = *base.last().expect("n > 0");
        let mut bursts = Vec::new();
        let mut k = 1usize;
        while bursts.len() < n && k as f64 * self.flash_every_s * 1000.0 <= horizon {
            let burst_ms = k as f64 * self.flash_every_s * 1000.0;
            // 0.1 ms apart so offsets stay strictly ordered in a burst
            for j in 0..self.flash_size {
                bursts.push(burst_ms + j as f64 * 0.1);
            }
            k += 1;
        }
        let mut out = Vec::with_capacity(n);
        let (mut i, mut j) = (0, 0);
        while out.len() < n {
            let take_base = match (base.get(i), bursts.get(j)) {
                (Some(b), Some(u)) => b <= u,
                (Some(_), None) => true,
                (None, Some(_)) => false,
                (None, None) => unreachable!("base holds n arrivals"),
            };
            if take_base {
                out.push(base[i]);
                i += 1;
            } else {
                out.push(bursts[j]);
                j += 1;
            }
        }
        out
    }

    /// Generate the fleet timeline: `n` paper-style QoS draws, each
    /// assigned a weighted device class (budget scaled by the class,
    /// class id encoded into the seed) and stamped with a diurnal +
    /// flash-crowd arrival time.
    pub fn timeline(&self, n: usize, rng: &mut Pcg32) -> Vec<TimedRequest> {
        assert!(!self.classes.is_empty(), "fleet needs at least one device class");
        assert!(
            self.classes.iter().all(|c| c.weight > 0.0),
            "class weights must be positive"
        );
        let mut gen = WorkloadGen::paper(self.net);
        gen.inferences_per_request = self.inferences_per_request;
        let total: f64 = self.classes.iter().map(|c| c.weight).sum();
        let k = self.classes.len() as u64;
        let mut tl = timeline(
            &gen,
            &ArrivalProcess::Trace { times_ms: self.arrival_times_ms(n, rng) },
            n,
            rng,
        );
        for tr in &mut tl {
            // weighted class draw, then fold the class into the seed's
            // low bits: seed = noise·K + class, so class_of(seed) is
            // exact and the remaining bits stay per-request noise
            let mut x = rng.f64() * total;
            let mut class = self.classes.len() - 1;
            for (c, spec) in self.classes.iter().enumerate() {
                if x < spec.weight {
                    class = c;
                    break;
                }
                x -= spec.weight;
            }
            tr.request.qos_ms *= self.classes[class].qos_scale;
            tr.request.seed = (tr.request.seed / k) * k + class as u64;
        }
        tl
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> FleetSpec {
        FleetSpec::synthetic(Network::Vgg16, 1000, 200.0)
    }

    fn nondecreasing(xs: &[f64]) -> bool {
        xs.windows(2).all(|w| w[0] <= w[1])
    }

    #[test]
    fn timeline_is_deterministic_and_seed_sensitive() {
        let s = spec();
        let a = s.timeline(500, &mut Pcg32::seeded(9));
        let b = s.timeline(500, &mut Pcg32::seeded(9));
        let c = s.timeline(500, &mut Pcg32::seeded(10));
        let key =
            |tl: &[TimedRequest]| tl.iter().map(|t| (t.arrival_ms, t.request.seed)).collect::<Vec<_>>();
        assert_eq!(key(&a), key(&b));
        assert_ne!(key(&a), key(&c));
        assert_eq!(a.len(), 500);
        assert!(nondecreasing(&a.iter().map(|t| t.arrival_ms).collect::<Vec<_>>()));
        for (i, tr) in a.iter().enumerate() {
            assert_eq!(tr.request.id, i);
        }
    }

    #[test]
    fn class_encoding_roundtrips_and_matches_weights() {
        let s = spec();
        let tl = s.timeline(4000, &mut Pcg32::seeded(3));
        let mut counts = vec![0usize; s.class_count()];
        for tr in &tl {
            counts[s.class_of(tr.request.seed)] += 1;
        }
        // 0.5 / 0.3 / 0.2 within generous sampling tolerance
        assert!((1700..=2300).contains(&counts[0]), "reference {counts:?}");
        assert!((900..=1500).contains(&counts[1]), "throttled {counts:?}");
        assert!((500..=1100).contains(&counts[2]), "low-power {counts:?}");
        // device ids stay within the fleet
        assert!(tl.iter().all(|t| s.device_of(t.request.seed) < s.devices));
    }

    #[test]
    fn slower_classes_get_looser_deadlines() {
        let s = spec();
        let tl = s.timeline(4000, &mut Pcg32::seeded(5));
        let mean_qos = |class: usize| {
            let qs: Vec<f64> = tl
                .iter()
                .filter(|t| s.class_of(t.request.seed) == class)
                .map(|t| t.request.qos_ms)
                .collect();
            qs.iter().sum::<f64>() / qs.len().max(1) as f64
        };
        let (fast, slow) = (mean_qos(0), mean_qos(2));
        assert!(
            slow > fast * 1.5,
            "low-power budgets must be looser: {fast} vs {slow}"
        );
    }

    #[test]
    fn diurnal_rate_modulates_arrival_density() {
        let mut s = spec();
        s.flash_size = 0; // isolate the diurnal shape
        let times = s.arrival_times_ms(20_000, &mut Pcg32::seeded(7));
        assert!(nondecreasing(&times));
        // first quarter-period (sin > 0: above mean rate) vs the third
        // (sin < 0: below): the peak window must hold clearly more
        let period_ms = s.period_s * 1000.0;
        let quarter = period_ms / 4.0;
        let in_window = |lo: f64, hi: f64| times.iter().filter(|&&t| t >= lo && t < hi).count();
        let peak = in_window(0.0, quarter) + in_window(period_ms, period_ms + quarter);
        let trough =
            in_window(2.0 * quarter, 3.0 * quarter) + in_window(period_ms + 2.0 * quarter, period_ms + 3.0 * quarter);
        assert!(
            peak as f64 > trough as f64 * 1.5,
            "peak {peak} vs trough {trough}"
        );
    }

    #[test]
    fn flash_crowds_fire_on_schedule() {
        let s = spec();
        let times = s.arrival_times_ms(20_000, &mut Pcg32::seeded(11));
        assert!(nondecreasing(&times));
        let burst_ms = s.flash_every_s * 1000.0;
        let in_burst = times
            .iter()
            .filter(|&&t| (burst_ms..burst_ms + s.flash_size as f64 * 0.1 + 1.0).contains(&t))
            .count();
        assert!(
            in_burst >= s.flash_size,
            "flash crowd missing at {burst_ms} ms: {in_burst} arrivals"
        );
    }

    #[test]
    fn zero_depth_reduces_to_steady_poisson() {
        let mut s = spec();
        s.diurnal_depth = 0.0;
        s.flash_size = 0;
        let times = s.arrival_times_ms(20_000, &mut Pcg32::seeded(13));
        // 200 req/s => mean gap 5 ms => 20k arrivals in ~100 s
        let mean_gap = times.last().unwrap() / 20_000.0;
        assert!((4.5..5.5).contains(&mean_gap), "mean gap {mean_gap}");
    }
}
