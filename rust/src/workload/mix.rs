//! Network mixes: one open-loop workload interleaving several networks.
//!
//! Mixed-network serving (DESIGN.md §12) feeds one admission queue with
//! requests targeting different networks.  [`NetworkMix`] holds the
//! target proportions (`--mix vgg16=0.7,vit=0.3`), and
//! [`mixed_timeline`] draws one timeline from it: each request's
//! network is sampled i.i.d. from the mix, its QoS level comes from
//! **its own network's** generator (so every request's deadline spectrum
//! matches its network's Table-2 latency bounds — a vit deadline drawn
//! from vgg16 bounds would be unservable by construction), and arrival
//! times come from one shared [`ArrivalProcess`] — the networks share
//! the queue, not just the clock.
//!
//! Generation is deterministic given the RNG seed, and request ids are
//! the global timeline positions — the properties the mixed
//! baseline-equivalence test relies on.

use anyhow::{bail, Result};

use crate::space::Network;
use crate::util::rng::Pcg32;

use super::arrival::{ArrivalProcess, TimedRequest};
use super::{Request, WorkloadGen};

/// Target proportions of each network in a mixed workload.  Weights are
/// normalized at construction; zero-weight entries are dropped.
#[derive(Debug, Clone)]
pub struct NetworkMix {
    /// `(network, normalized share)`, shares sum to 1.
    weights: Vec<(Network, f64)>,
}

impl NetworkMix {
    /// Validate and normalize `(network, weight)` pairs: weights must be
    /// finite and non-negative, sum positive, networks distinct.
    pub fn new(weights: &[(Network, f64)]) -> Result<NetworkMix> {
        let mut kept: Vec<(Network, f64)> = Vec::new();
        for &(net, w) in weights {
            if !w.is_finite() || w < 0.0 {
                bail!("bad mix weight {w} for {}", net.name());
            }
            if kept.iter().any(|(n, _)| *n == net) {
                bail!("network {} listed twice in the mix", net.name());
            }
            if w > 0.0 {
                kept.push((net, w));
            }
        }
        let total: f64 = kept.iter().map(|(_, w)| w).sum();
        if kept.is_empty() || total <= 0.0 {
            bail!("a network mix needs at least one positive weight");
        }
        for (_, w) in &mut kept {
            *w /= total;
        }
        Ok(NetworkMix { weights: kept })
    }

    /// Everything on one network (the degenerate single-network mix).
    pub fn single(net: Network) -> NetworkMix {
        NetworkMix { weights: vec![(net, 1.0)] }
    }

    /// Parse the CLI form `net=weight[,net=weight…]`, e.g.
    /// `vgg16=0.7,vit=0.3`.
    pub fn parse(s: &str) -> Result<NetworkMix> {
        let mut weights = Vec::new();
        for part in s.split(',') {
            let part = part.trim();
            let Some((name, value)) = part.split_once('=') else {
                bail!("bad mix component {part:?} (expected net=weight, e.g. vgg16=0.7)");
            };
            let Ok(w) = value.trim().parse::<f64>() else {
                bail!("bad mix weight {value:?} for {name:?}");
            };
            weights.push((Network::parse(name.trim())?, w));
        }
        NetworkMix::new(&weights)
    }

    /// Networks with a positive share, in declaration order.
    pub fn networks(&self) -> Vec<Network> {
        self.weights.iter().map(|(n, _)| *n).collect()
    }

    /// Normalized share of `net` (0 when absent).
    pub fn share(&self, net: Network) -> f64 {
        self.weights.iter().find(|(n, _)| *n == net).map_or(0.0, |(_, w)| *w)
    }

    /// Draw one network from the mix.
    pub fn sample(&self, rng: &mut Pcg32) -> Network {
        let x = rng.uniform(0.0, 1.0);
        let mut acc = 0.0;
        for &(net, w) in &self.weights {
            acc += w;
            if x < acc {
                return net;
            }
        }
        // floating-point slack at x ≈ 1.0
        self.weights.last().expect("non-empty by construction").0
    }
}

/// Generate a mixed timed workload: `n` requests whose networks are
/// drawn from `mix`, QoS levels from each network's own generator
/// (`gen_for`), and arrival times from one shared `process`.  Request
/// ids are the global timeline positions (0..n).
pub fn mixed_timeline<G>(
    mix: &NetworkMix,
    gen_for: G,
    process: &ArrivalProcess,
    n: usize,
    rng: &mut Pcg32,
) -> Vec<TimedRequest>
where
    G: Fn(Network) -> WorkloadGen,
{
    let assignment: Vec<Network> = (0..n).map(|_| mix.sample(rng)).collect();
    // per-network request queues: each network's QoS draws are rescaled
    // over that network's own bounds (WorkloadGen needs ≥ 2 draws to pin
    // its rescale, so a 1-request network draws 2 and keeps the first)
    let mut queues: Vec<(Network, std::collections::VecDeque<Request>)> = mix
        .networks()
        .into_iter()
        .map(|net| {
            let count = assignment.iter().filter(|&&a| a == net).count();
            let requests = if count == 0 {
                std::collections::VecDeque::new()
            } else {
                gen_for(net).generate(count.max(2), rng).into_iter().take(count).collect()
            };
            (net, requests)
        })
        .collect();
    let times = process.times_ms(n, rng);
    assignment
        .iter()
        .zip(times)
        .enumerate()
        .map(|(id, (&net, arrival_ms))| {
            let mut request = queues
                .iter_mut()
                .find(|(qn, _)| *qn == net)
                .and_then(|(_, q)| q.pop_front())
                .expect("queues sized to the assignment");
            request.id = id;
            TimedRequest { request, arrival_ms }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::LatencyBounds;

    #[test]
    fn parse_normalizes_and_orders() {
        let mix = NetworkMix::parse("vgg16=0.7,vit=0.3").unwrap();
        assert_eq!(mix.networks(), vec![Network::Vgg16, Network::Vit]);
        assert!((mix.share(Network::Vgg16) - 0.7).abs() < 1e-12);
        assert!((mix.share(Network::Vit) - 0.3).abs() < 1e-12);
        // unnormalized weights normalize
        let mix = NetworkMix::parse("vgg16=3,vit=1").unwrap();
        assert!((mix.share(Network::Vgg16) - 0.75).abs() < 1e-12);
        // zero weights drop out
        let mix = NetworkMix::parse("vgg16=1,vit=0").unwrap();
        assert_eq!(mix.networks(), vec![Network::Vgg16]);
        assert_eq!(mix.share(Network::Vit), 0.0);
    }

    #[test]
    fn parse_rejects_malformed_mixes() {
        assert!(NetworkMix::parse("").is_err());
        assert!(NetworkMix::parse("vgg16").is_err(), "missing =weight");
        assert!(NetworkMix::parse("vgg16=x").is_err(), "non-numeric weight");
        assert!(NetworkMix::parse("resnet=1").is_err(), "unknown network");
        assert!(NetworkMix::parse("vgg16=-1,vit=2").is_err(), "negative weight");
        assert!(NetworkMix::parse("vgg16=0,vit=0").is_err(), "all-zero mix");
        assert!(NetworkMix::parse("vgg16=1,vgg16=1").is_err(), "duplicate network");
    }

    #[test]
    fn sample_tracks_the_target_shares() {
        let mix = NetworkMix::parse("vgg16=0.7,vit=0.3").unwrap();
        let mut rng = Pcg32::seeded(11);
        let n = 20_000;
        let vgg = (0..n).filter(|_| mix.sample(&mut rng) == Network::Vgg16).count();
        let share = vgg as f64 / n as f64;
        assert!((share - 0.7).abs() < 0.02, "observed vgg16 share {share}");
    }

    #[test]
    fn mixed_timeline_ids_are_global_and_qos_respects_each_networks_bounds() {
        let mix = NetworkMix::parse("vgg16=0.7,vit=0.3").unwrap();
        let mut rng = Pcg32::seeded(12);
        let tl = mixed_timeline(
            &mix,
            WorkloadGen::paper,
            &ArrivalProcess::Poisson { rate_per_s: 100.0 },
            300,
            &mut rng,
        );
        assert_eq!(tl.len(), 300);
        let mut seen = [0usize; 2];
        for (i, tr) in tl.iter().enumerate() {
            assert_eq!(tr.request.id, i, "ids are timeline positions");
            let b = LatencyBounds::paper(tr.request.net);
            assert!(
                tr.request.qos_ms >= b.min_ms - 1e-9 && tr.request.qos_ms <= b.max_ms + 1e-9,
                "request {i} ({:?}) qos {} outside its network's bounds",
                tr.request.net,
                tr.request.qos_ms
            );
            seen[(tr.request.net == Network::Vit) as usize] += 1;
        }
        assert!(seen[0] > 0 && seen[1] > 0, "both networks present: {seen:?}");
        assert!(seen[0] > seen[1], "the 70% network dominates");
        // arrivals nondecreasing (one shared process)
        assert!(tl.windows(2).all(|w| w[0].arrival_ms <= w[1].arrival_ms));
    }

    #[test]
    fn mixed_timeline_is_deterministic_given_the_seed() {
        let mix = NetworkMix::parse("vgg16=0.5,vit=0.5").unwrap();
        let make = || {
            let mut rng = Pcg32::seeded(13);
            mixed_timeline(
                &mix,
                WorkloadGen::paper,
                &ArrivalProcess::Poisson { rate_per_s: 50.0 },
                64,
                &mut rng,
            )
        };
        let (a, b) = (make(), make());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.request.net, y.request.net);
            assert_eq!(x.request.qos_ms, y.request.qos_ms);
            assert_eq!(x.request.seed, y.request.seed);
            assert_eq!(x.arrival_ms, y.arrival_ms);
        }
    }

    #[test]
    fn single_network_mix_reduces_to_one_network() {
        let mix = NetworkMix::single(Network::Vit);
        let mut rng = Pcg32::seeded(14);
        let tl = mixed_timeline(
            &mix,
            WorkloadGen::paper,
            &ArrivalProcess::Poisson { rate_per_s: 100.0 },
            32,
            &mut rng,
        );
        assert!(tl.iter().all(|tr| tr.request.net == Network::Vit));
    }

    #[test]
    fn tiny_mixed_timelines_stay_well_formed() {
        // the count.max(2) guard: a network assigned exactly one request
        // still draws a valid (bounds-clamped) QoS level
        let mix = NetworkMix::parse("vgg16=0.99,vit=0.01").unwrap();
        for seed in 0..20 {
            let mut rng = Pcg32::seeded(seed);
            let tl = mixed_timeline(
                &mix,
                WorkloadGen::paper,
                &ArrivalProcess::Poisson { rate_per_s: 100.0 },
                8,
                &mut rng,
            );
            assert_eq!(tl.len(), 8);
            for tr in &tl {
                let b = LatencyBounds::paper(tr.request.net);
                assert!(tr.request.qos_ms >= b.min_ms - 1e-9);
                assert!(tr.request.qos_ms <= b.max_ms + 1e-9);
            }
        }
    }
}
