//! Open-loop arrival processes for the serving pipeline.
//!
//! The paper evaluates the controller one request at a time; the serving
//! pipeline instead feeds a *stream* of requests into a bounded
//! admission queue at times drawn from an arrival process — open-loop,
//! i.e. arrivals do not wait for completions (the SplitPlace /
//! Dynamic-Split-Computing serving setting, see PAPERS.md).  Three
//! processes cover the interesting traffic shapes:
//!
//! * [`ArrivalProcess::Poisson`] — memoryless steady load;
//! * [`ArrivalProcess::Bursty`]  — Poisson base load plus periodic
//!   back-to-back bursts (flash-crowd pressure on the queue);
//! * [`ArrivalProcess::Trace`]   — replay of explicit arrival offsets,
//!   tiled when more requests than trace entries are needed.

use super::{Request, WorkloadGen};
use crate::util::rng::Pcg32;

/// How request arrival times are generated (all offsets in ms from the
/// experiment start, nondecreasing).
#[derive(Debug, Clone)]
pub enum ArrivalProcess {
    /// Exponential i.i.d. inter-arrivals at `rate_per_s`.
    Poisson { rate_per_s: f64 },
    /// Poisson base traffic at `base_rate_per_s`, plus `burst_size`
    /// back-to-back arrivals every `period_s` seconds.
    Bursty { base_rate_per_s: f64, period_s: f64, burst_size: usize },
    /// Replay explicit arrival offsets (ms, nondecreasing).  Requesting
    /// more arrivals than the trace holds tiles it end-to-end.
    Trace { times_ms: Vec<f64> },
}

impl ArrivalProcess {
    /// Draw `n` nondecreasing arrival offsets (ms).
    pub fn times_ms(&self, n: usize, rng: &mut Pcg32) -> Vec<f64> {
        match self {
            ArrivalProcess::Poisson { rate_per_s } => {
                assert!(*rate_per_s > 0.0, "Poisson rate must be positive");
                let mean_gap_ms = 1000.0 / rate_per_s;
                let mut t = 0.0;
                (0..n)
                    .map(|_| {
                        t += rng.weibull(1.0, mean_gap_ms);
                        t
                    })
                    .collect()
            }
            ArrivalProcess::Bursty { base_rate_per_s, period_s, burst_size } => {
                assert!(*base_rate_per_s > 0.0, "base rate must be positive");
                assert!(*period_s > 0.0, "burst period must be positive");
                assert!(*burst_size >= 1, "burst size must be >= 1");
                assert!(
                    *burst_size as f64 * 0.1 < period_s * 1000.0,
                    "burst span must fit within one period"
                );
                if n == 0 {
                    return Vec::new();
                }
                // Two sorted streams merged: the base stream alone could
                // supply all n arrivals, so bursts beyond its n-th
                // arrival (or beyond n entries) cannot make the cut.
                let mean_gap_ms = 1000.0 / base_rate_per_s;
                let mut base = Vec::with_capacity(n);
                let mut t = 0.0;
                for _ in 0..n {
                    t += rng.weibull(1.0, mean_gap_ms);
                    base.push(t);
                }
                let horizon = *base.last().expect("n > 0");
                let mut bursts = Vec::new();
                let mut k = 1usize; // bursts fire at k * period
                while bursts.len() < n && k as f64 * period_s * 1000.0 <= horizon {
                    let burst_ms = k as f64 * period_s * 1000.0;
                    // back-to-back arrivals, 0.1 ms apart so offsets stay
                    // strictly ordered within the burst
                    for j in 0..*burst_size {
                        bursts.push(burst_ms + j as f64 * 0.1);
                    }
                    k += 1;
                }
                let mut out = Vec::with_capacity(n);
                let (mut i, mut j) = (0, 0);
                while out.len() < n {
                    let take_base = match (base.get(i), bursts.get(j)) {
                        (Some(b), Some(u)) => b <= u,
                        (Some(_), None) => true,
                        (None, Some(_)) => false,
                        (None, None) => unreachable!("base holds n arrivals"),
                    };
                    if take_base {
                        out.push(base[i]);
                        i += 1;
                    } else {
                        out.push(bursts[j]);
                        j += 1;
                    }
                }
                out
            }
            ArrivalProcess::Trace { times_ms } => {
                assert!(!times_ms.is_empty(), "empty arrival trace");
                let span = times_ms.last().expect("non-empty") + 1.0;
                (0..n)
                    .map(|i| times_ms[i % times_ms.len()] + (i / times_ms.len()) as f64 * span)
                    .collect()
            }
        }
    }
}

/// One request stamped with its arrival time — what the admission queue
/// holds.  The QoS deadline travels with the request: by `deadline_ms`
/// (absolute, experiment clock) the response should be out.
#[derive(Debug, Clone)]
pub struct TimedRequest {
    pub request: Request,
    /// Arrival offset from the experiment start (ms).
    pub arrival_ms: f64,
}

impl TimedRequest {
    /// Absolute response deadline: arrival + the request's QoS level.
    pub fn deadline_ms(&self) -> f64 {
        self.arrival_ms + self.request.qos_ms
    }
}

/// Generate a timed workload: `n` paper-style requests stamped with
/// arrival times from `process`.
pub fn timeline(
    gen: &WorkloadGen,
    process: &ArrivalProcess,
    n: usize,
    rng: &mut Pcg32,
) -> Vec<TimedRequest> {
    let requests = gen.generate(n, rng);
    let times = process.times_ms(n, rng);
    requests
        .into_iter()
        .zip(times)
        .map(|(request, arrival_ms)| TimedRequest { request, arrival_ms })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::space::Network;

    fn nondecreasing(xs: &[f64]) -> bool {
        xs.windows(2).all(|w| w[0] <= w[1])
    }

    #[test]
    fn poisson_times_are_ordered_with_matching_mean_rate() {
        let p = ArrivalProcess::Poisson { rate_per_s: 100.0 };
        let mut rng = Pcg32::seeded(1);
        let t = p.times_ms(20_000, &mut rng);
        assert_eq!(t.len(), 20_000);
        assert!(nondecreasing(&t));
        // 100 req/s => mean gap 10 ms => 20k arrivals in ~200 s
        let mean_gap = t.last().unwrap() / 20_000.0;
        assert!((9.0..11.0).contains(&mean_gap), "mean gap {mean_gap}");
    }

    #[test]
    fn bursty_contains_bursts_and_base_traffic() {
        let p = ArrivalProcess::Bursty {
            base_rate_per_s: 20.0,
            period_s: 1.0,
            burst_size: 16,
        };
        let mut rng = Pcg32::seeded(2);
        let t = p.times_ms(400, &mut rng);
        assert!(nondecreasing(&t));
        // the first burst lands at exactly 1000 ms: its 16 arrivals (plus
        // possibly a coinciding base arrival) within 2 ms
        let in_burst = t.iter().filter(|&&x| (1000.0..1002.0).contains(&x)).count();
        assert!((16..=18).contains(&in_burst), "{in_burst} arrivals in the burst window");
        // base traffic exists between bursts
        let before = t.iter().filter(|&&x| x < 1000.0).count();
        assert!(before > 5, "only {before} base arrivals in the first second");
    }

    #[test]
    fn trace_replays_and_tiles() {
        let p = ArrivalProcess::Trace { times_ms: vec![0.0, 5.0, 9.0] };
        let mut rng = Pcg32::seeded(3);
        let t = p.times_ms(7, &mut rng);
        assert_eq!(t, vec![0.0, 5.0, 9.0, 10.0, 15.0, 19.0, 20.0]);
    }

    #[test]
    fn timeline_pairs_requests_with_times() {
        let gen = WorkloadGen::paper(Network::Vgg16);
        let mut rng = Pcg32::seeded(4);
        let tl = timeline(&gen, &ArrivalProcess::Poisson { rate_per_s: 50.0 }, 64, &mut rng);
        assert_eq!(tl.len(), 64);
        for (i, tr) in tl.iter().enumerate() {
            assert_eq!(tr.request.id, i);
            assert!(tr.deadline_ms() >= tr.arrival_ms + 90.0, "deadline before arrival");
        }
        assert!(nondecreasing(&tl.iter().map(|t| t.arrival_ms).collect::<Vec<_>>()));
    }

    #[test]
    fn deterministic_given_seed() {
        let p = ArrivalProcess::Poisson { rate_per_s: 10.0 };
        let a = p.times_ms(100, &mut Pcg32::seeded(7));
        let b = p.times_ms(100, &mut Pcg32::seeded(7));
        assert_eq!(a, b);
    }
}
