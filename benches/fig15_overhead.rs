//! Bench: regenerate Fig. 15 (controller overhead) + micro-bench the two
//! controller operations the paper times: configuration selection
//! (Algorithm 1) and configuration application.

use dynasplit::controller::algorithm1;
use dynasplit::controller::apply::Applier;
use dynasplit::experiments::{overhead, Ctx};
use dynasplit::solver::{Solver, Strategy};
use dynasplit::space::Network;
use dynasplit::util::bench::Bencher;
use dynasplit::util::rng::Pcg32;

fn main() {
    let mut b = Bencher::from_env();
    let ctx = Ctx::load(&dynasplit::artifacts_dir(None));
    b.run_once("fig15_overhead_analysis", || {
        let results: Vec<_> = Network::ALL
            .iter()
            .map(|&net| overhead::run(&ctx, net, 50, 1000, 42))
            .collect();
        overhead::print_report(&results);
    });

    // --- micro: Algorithm-1 selection over a paper-sized config set ---
    let mut solver = Solver::new(&ctx.testbed, Network::Vgg16);
    solver.batch_per_trial = 200;
    let out = solver.run(Strategy::NsgaIII, solver.trials_for_fraction(0.2), 42);
    let mut sorted = out.pareto.clone();
    algorithm1::sort_config_set(&mut sorted);
    let mut qos = 80.0;
    b.bench("algorithm1_select", || {
        qos = if qos > 5000.0 { 80.0 } else { qos + 37.0 };
        algorithm1::select(&sorted, qos).expect("non-empty set").config
    });

    // --- micro: configuration application state machine ---
    let mut applier = Applier::default();
    let mut rng = Pcg32::seeded(3);
    let space = dynasplit::space::Space::new(Network::Vgg16);
    let pool: Vec<_> = (0..13).map(|_| space.sample(&mut rng)).collect();
    let mut i = 0;
    b.bench("applier_state_machine", || {
        i = (i + 1) % pool.len();
        applier.apply(&pool[i], &mut rng)
    });
    b.finish();
}
