//! Bench: regenerate Fig. 2a–2e (preliminary study).
//!
//! Prints the five panels as tables with the paper-shape commentary and
//! records the end-to-end wall time.

use dynasplit::experiments::{prelim, Ctx};
use dynasplit::util::bench::Bencher;

fn main() {
    let mut b = Bencher::from_env();
    let ctx = Ctx::load(&dynasplit::artifacts_dir(None));
    b.run_once("fig2_prelim_study", || {
        let r = prelim::run(&ctx, 1000, 42);
        prelim::print_report(&r);
    });
    b.finish();
}
