//! Bench: regenerate Table 2 (latency bounds + achieving configurations)
//! via the full feasible-space grid sweep.

use dynasplit::experiments::{bounds, Ctx};
use dynasplit::space::Network;
use dynasplit::util::bench::Bencher;

fn main() {
    let mut b = Bencher::from_env();
    let ctx = Ctx::load(&dynasplit::artifacts_dir(None));
    b.run_once("table2_latency_bounds", || {
        let vgg = bounds::run(&ctx, Network::Vgg16, 200, 42);
        let vit = bounds::run(&ctx, Network::Vit, 200, 42);
        bounds::print_report(&vgg, &vit);
    });
    b.finish();
}
