//! Bench: regenerate Fig. 10 (20% NSGA-III vs ~80% grid search) and
//! compare the fronts by hypervolume.

use dynasplit::experiments::{ablation, Ctx};
use dynasplit::nsga::hypervolume::hypervolume;
use dynasplit::solver::{Solver, Strategy};
use dynasplit::space::Network;
use dynasplit::util::bench::Bencher;

fn main() {
    let mut b = Bencher::from_env();
    let ctx = Ctx::load(&dynasplit::artifacts_dir(None));
    b.run_once("fig10_search_ablation", || {
        let r = ablation::run(&ctx, 50, 1000, 42);
        ablation::print_report(&r);
    });
    b.run_once("fig10_front_hypervolume", || {
        let mut solver = Solver::new(&ctx.testbed, Network::Vgg16);
        solver.batch_per_trial = 300;
        let refp = [12_000.0, 200.0, 0.0];
        for (name, strategy, frac) in [
            ("20% NSGA-III", Strategy::NsgaIII, 0.2),
            ("80% grid", Strategy::Grid, 0.815),
        ] {
            let out = solver.run(strategy, solver.trials_for_fraction(frac), 42);
            let pts: Vec<[f64; 3]> = out
                .pareto
                .iter()
                .map(|p| [p.latency_ms, p.energy_j, -p.accuracy])
                .collect();
            println!(
                "{name}: {} trials -> front {} entries, hypervolume {:.3e}",
                out.trials.len(),
                out.pareto.len(),
                hypervolume(&pts, &refp)
            );
        }
        println!("paper: the 20% search is sufficient (§6.3.4).");
    });
    b.finish();
}
