//! Bench: regenerate Fig. 5 (QoS request distributions) + micro-bench the
//! workload generator itself.

use dynasplit::experiments::workload_dist;
use dynasplit::space::Network;
use dynasplit::util::bench::Bencher;
use dynasplit::util::rng::Pcg32;
use dynasplit::workload::WorkloadGen;

fn main() {
    let mut b = Bencher::from_env();
    b.run_once("fig5_workload_distributions", || {
        let dists = [
            workload_dist::run(Network::Vgg16, 10_000, 42),
            workload_dist::run(Network::Vit, 10_000, 42),
        ];
        workload_dist::print_report(&dists);
    });
    let gen = WorkloadGen::paper(Network::Vgg16);
    let mut rng = Pcg32::seeded(1);
    b.bench("workload_generate_10k", || gen.generate(10_000, &mut rng));
    b.finish();
}
