//! Bench: §2.2 finding (i) (small models) and the §6.6 extension
//! ablations (serverless cold starts, QoS-clustered scheduling).

use dynasplit::experiments::{extensions, small_models, Ctx};
use dynasplit::util::bench::Bencher;

fn main() {
    let mut b = Bencher::from_env();
    let ctx = Ctx::load(&dynasplit::artifacts_dir(None));
    b.run_once("finding_i_small_models", || {
        small_models::print_report(&small_models::run());
    });
    b.run_once("ext_serverless_cold_start", || {
        let r = extensions::run_cold_start(&ctx, 50, 800.0, 42);
        extensions::print_cold_start(&r);
    });
    b.run_once("ext_qos_clustering", || {
        let r = extensions::run_clustering(&ctx, 100, 6, 42);
        extensions::print_clustering(&r);
    });
    b.finish();
}
