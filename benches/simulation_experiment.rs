//! Bench: regenerate the Simulation Experiment — Fig. 11 (scheduling),
//! Fig. 12 (latency), Fig. 13 (QoS violations), Fig. 14 (energy) at the
//! paper's full 10,000-request scale.

use dynasplit::experiments::{simulation, Ctx};
use dynasplit::space::Network;
use dynasplit::util::bench::Bencher;

fn main() {
    let mut b = Bencher::from_env();
    let ctx = Ctx::load(&dynasplit::artifacts_dir(None));
    for net in Network::ALL {
        b.run_once(&format!("fig11_to_14_simulation_{}", net.name()), || {
            let exp = simulation::run(&ctx, net, 10_000, 1000, 42);
            simulation::print_report(&exp);
        });
    }
    b.finish();
}
