//! Micro benchmarks of the coordinator's hot paths (§Perf L3):
//! runtime kernels (naive loops vs im2col+GEMM), whole-network forwards
//! (allocating vs arena vs threaded), serve-batch head amortization,
//! trial simulation, NSGA-III machinery, meter integration, transport
//! framing, JSON parsing, and — when artifacts are present — the real
//! PJRT layer execution path.
//!
//! Record the runtime perf trajectory with
//! `cargo bench --bench micro -- runtime --json BENCH_runtime.json`.

use std::sync::{Arc, Mutex};

use dynasplit::adapt::{ConfigStore, Sample, Telemetry};
use dynasplit::controller::algorithm1::{self, SelectIndex};
use dynasplit::controller::policy::ConfigSet;
use dynasplit::controller::Executor;
use dynasplit::model::manifest::LayerEntry;
use dynasplit::model::{Manifest, NetCost};
use dynasplit::nsga::{refpoints, sort};
use dynasplit::runtime::{kernels, InferenceBackend, NetworkRuntime, ReferenceBackend, TensorArena};
use dynasplit::serve::{BatchLog, BatchRuntimeExecutor};
use dynasplit::simulator::meter::{Meter, PowerTrace};
use dynasplit::simulator::Testbed;
use dynasplit::solver::ParetoEntry;
use dynasplit::space::{Config, Network, Space, TpuMode};
use dynasplit::transport::frame::Frame;
use dynasplit::util::bench::Bencher;
use dynasplit::util::json::Json;
use dynasplit::util::rng::Pcg32;
use dynasplit::workload::Request;

fn ramp(n: usize, step: f32) -> Vec<f32> {
    (0..n).map(|i| (i as f32 * step).sin()).collect()
}

/// Small VGG-ish stack for forward benches: 3 convs (one strided), a
/// flatten-dense, a classifier head.
fn bench_layers() -> Vec<LayerEntry> {
    vec![
        LayerEntry::synthetic(0, vec![16, 16, 8], vec![16, 16, 16]),
        LayerEntry::synthetic(1, vec![16, 16, 16], vec![8, 8, 24]),
        LayerEntry::synthetic(2, vec![8, 8, 24], vec![8, 8, 16]),
        LayerEntry::synthetic(3, vec![8, 8, 16], vec![64]),
        LayerEntry::synthetic(4, vec![64], vec![10]),
    ]
}

fn bench_runtime(backend: ReferenceBackend, batch: usize) -> NetworkRuntime {
    NetworkRuntime::from_layers(&backend, Network::Vgg16, batch, &bench_layers(), None)
        .expect("reference runtime")
}

fn main() {
    let mut b = Bencher::from_env();
    let tb = Testbed::synthetic();
    let space = Space::new(Network::Vgg16);
    let mut rng = Pcg32::seeded(1);

    // --- simulator ---
    let configs: Vec<_> = (0..64).map(|_| space.sample(&mut rng)).collect();
    let mut ci = 0;
    b.bench("testbed_trial_1000_inferences", || {
        ci = (ci + 1) % configs.len();
        tb.run_trial_n(&configs[ci], 1000, &mut rng).latency_ms
    });
    b.bench("device_latency_model", || {
        ci = (ci + 1) % configs.len();
        tb.vgg.latency(&configs[ci]).total_s()
    });

    // --- meter ---
    let mut trace = PowerTrace::new();
    for i in 0..2000 {
        trace.push(0.2, 3.0 + (i % 7) as f64 * 0.3);
    }
    let meter = Meter::edge();
    b.bench("meter_sample_2000seg_trace", || meter.measure_energy_j(&trace, &mut rng));

    // --- Algorithm-1 selection: O(n) scan vs O(log n) index ---
    // The paper's set holds ~12-15 entries; production-scale stores can
    // hold thousands.  Same QoS sequence for both variants at each n.
    for &n in &[100usize, 1_000, 10_000] {
        let mut entries: Vec<ParetoEntry> = (0..n)
            .map(|_| ParetoEntry {
                config: space.sample(&mut rng),
                latency_ms: rng.uniform(50.0, 5000.0),
                energy_j: rng.uniform(1.0, 100.0),
                accuracy: rng.uniform(0.9, 1.0),
            })
            .collect();
        algorithm1::sort_config_set(&mut entries);
        let index = SelectIndex::build(&entries);
        let qos: Vec<f64> = (0..256).map(|_| rng.uniform(10.0, 6000.0)).collect();
        let mut qi = 0;
        b.bench(&format!("select_scan_n{n}"), || {
            qi = (qi + 1) % qos.len();
            algorithm1::select_pos(&entries, qos[qi])
        });
        let mut qj = 0;
        b.bench(&format!("select_index_n{n}"), || {
            qj = (qj + 1) % qos.len();
            index.select(qos[qj])
        });
        b.bench(&format!("select_index_build_n{n}"), || {
            SelectIndex::build(&entries).len()
        });
    }

    // --- runtime kernels: naive loops vs im2col+GEMM ---
    // The 4x-speedup headline case: 3x3 conv, 32x32 spatial, 16 -> 32
    // channels, stride 1 (the mid-network shape class that dominates
    // VGG-style forwards).
    {
        let (h, wd, ci, co) = (32usize, 32usize, 16usize, 32usize);
        let x = ramp(h * wd * ci, 0.37);
        let w = ramp(co * 9 * ci, 0.11);
        let bias = vec![0.01f32; co];
        let mut out = vec![0.0f32; h * wd * co];
        b.bench("runtime_conv3x3_32x32x16to32_naive", || {
            kernels::naive::conv3x3(&x, &w, &bias, h, wd, ci, h, wd, co, 1, &mut out);
            out[0]
        });
        let mut patches = Vec::new();
        b.bench("runtime_conv3x3_32x32x16to32_gemm", || {
            kernels::im2col_3x3(&x, h, wd, ci, h, wd, 1, &mut patches);
            kernels::gemm_bias_relu(&patches, &w, &bias, h * wd, co, 9 * ci, &mut out, 1);
            out[0]
        });
        b.bench("runtime_conv3x3_32x32x16to32_gemm_t4", || {
            kernels::im2col_3x3(&x, h, wd, ci, h, wd, 1, &mut patches);
            kernels::gemm_bias_relu(&patches, &w, &bias, h * wd, co, 9 * ci, &mut out, 4);
            out[0]
        });
        let conv_speedup = b.speedup(
            "runtime_conv3x3_32x32x16to32_naive",
            "runtime_conv3x3_32x32x16to32_gemm",
        );
        if let Some(s) = conv_speedup {
            println!("    >> conv3x3 im2col+gemm speedup vs naive: {s:.2}x (target >= 4x)");
        }
        // CI regression guard: DYNASPLIT_BENCH_ENFORCE=<floor> turns the
        // measured ratio into a hard gate (the 4x acceptance target is
        // recorded in BENCH_runtime.json; the CI floor is lower to stay
        // robust on noisy shared runners)
        if let Ok(floor) = std::env::var("DYNASPLIT_BENCH_ENFORCE") {
            let floor: f64 = floor.parse().expect("DYNASPLIT_BENCH_ENFORCE must be a number");
            let s = conv_speedup.expect(
                "DYNASPLIT_BENCH_ENFORCE needs both conv3x3_32x32x16to32 cases (check the filter)",
            );
            assert!(s >= floor, "conv3x3 gemm speedup {s:.2}x below enforced floor {floor}x");
            println!("    >> enforced: {s:.2}x >= {floor}x");
        }
        // strided variant: 32x32x16 -> 16x16x32
        let mut out2 = vec![0.0f32; 16 * 16 * co];
        b.bench("runtime_conv3x3_stride2_naive", || {
            kernels::naive::conv3x3(&x, &w, &bias, h, wd, ci, 16, 16, co, 2, &mut out2);
            out2[0]
        });
        b.bench("runtime_conv3x3_stride2_gemm", || {
            kernels::im2col_3x3(&x, h, wd, ci, 16, 16, 2, &mut patches);
            kernels::gemm_bias_relu(&patches, &w, &bias, 16 * 16, co, 9 * ci, &mut out2, 1);
            out2[0]
        });
    }
    // dense 1024 -> 1024: serial dot vs unrolled GEMV
    {
        let (n_in, n_out) = (1024usize, 1024usize);
        let x = ramp(n_in, 0.23);
        let w = ramp(n_out * n_in, 0.07);
        let bias = vec![0.02f32; n_out];
        let mut out = vec![0.0f32; n_out];
        b.bench("runtime_dense_1024x1024_naive", || {
            kernels::naive::dense(&x, &w, &bias, n_in, n_out, &mut out);
            out[0]
        });
        b.bench("runtime_dense_1024x1024_gemv", || {
            kernels::gemv_bias_relu(&w, &x, &bias, n_out, n_in, &mut out, 1);
            out[0]
        });
    }
    // whole-network forward, batch 4: naive oracle vs fast kernels,
    // allocating vs arena-reusing, single- vs multi-threaded
    {
        let batch = 4;
        let x = ramp(batch * 16 * 16 * 8, 0.19);
        let naive_rt = bench_runtime(ReferenceBackend::naive_oracle(), batch);
        b.bench("runtime_forward_b4_naive", || {
            naive_rt.run_full(0, &x).unwrap().len()
        });
        let fast_rt = bench_runtime(ReferenceBackend::new(), batch);
        b.bench("runtime_forward_b4_fast", || {
            fast_rt.run_full(0, &x).unwrap().len()
        });
        let mut arena = TensorArena::new();
        b.bench("runtime_forward_b4_fast_arena", || {
            fast_rt.run_full_in(0, &x, &mut arena).unwrap().len()
        });
        let threaded_rt = bench_runtime(ReferenceBackend::with_threads(2), batch);
        let mut arena2 = TensorArena::new();
        b.bench("runtime_forward_b4_fast_arena_t2", || {
            threaded_rt.run_full_in(0, &x, &mut arena2).unwrap().len()
        });
        if let Some(s) = b.speedup("runtime_forward_b4_naive", "runtime_forward_b4_fast_arena") {
            println!("    >> full forward fast+arena speedup vs naive: {s:.2}x");
        }
    }
    // serve-batch head amortization: 8 coalesced requests as one flat
    // [8, ...] head call vs 8 single-image calls
    {
        let config =
            Config { net: Network::Vgg16, cpu_idx: 6, tpu: TpuMode::Off, gpu: true, split: 3 };
        let requests: Vec<Request> = (0..8)
            .map(|id| Request {
                id,
                net: Network::Vgg16,
                qos_ms: 500.0,
                inferences: 1,
                seed: 100 + id as u64,
            })
            .collect();
        let refs: Vec<&Request> = requests.iter().collect();
        let log_batched = Arc::new(Mutex::new(BatchLog::default()));
        let mut batched =
            BatchRuntimeExecutor::new(bench_runtime(ReferenceBackend::new(), 1), log_batched.clone());
        b.bench("runtime_serve_head8_batched", || {
            log_batched.lock().unwrap().digests.clear();
            batched.execute_batch(&refs, &config).len()
        });
        let log_solo = Arc::new(Mutex::new(BatchLog::default()));
        let mut solo =
            BatchRuntimeExecutor::new(bench_runtime(ReferenceBackend::new(), 1), log_solo.clone());
        b.bench("runtime_serve_head8_per_request", || {
            log_solo.lock().unwrap().digests.clear();
            requests.iter().map(|r| solo.execute(r, &config).latency_ms).sum::<f64>()
        });
        if let Some(s) =
            b.speedup("runtime_serve_head8_per_request", "runtime_serve_head8_batched")
        {
            println!("    >> serve-batch head amortization speedup: {s:.2}x");
        }
    }

    // --- adapt path: store snapshot / hot-swap / telemetry record ---
    // The snapshot sits on every dispatch batch and the telemetry record
    // on every completed request — both must stay negligible next to
    // per-request inference.  The swap (sort + SelectIndex + digest
    // rebuild on a production-scale set) happens once per online
    // re-solve; its cost bounds how "live" a hot-swap can be.
    {
        let entries: Vec<ParetoEntry> = (0..1_000)
            .map(|_| ParetoEntry {
                config: space.sample(&mut rng),
                latency_ms: rng.uniform(50.0, 5000.0),
                energy_j: rng.uniform(1.0, 100.0),
                accuracy: rng.uniform(0.9, 1.0),
            })
            .collect();
        let store = ConfigStore::new(ConfigSet::new(entries.clone()));
        b.bench("runtime_adapt_store_snapshot", || store.snapshot().epoch());
        b.bench("runtime_adapt_store_swap_n1000", || {
            store.swap(ConfigSet::new(entries.clone()))
        });
        let telemetry = Telemetry::new(1, 256);
        let sample = Sample {
            epoch: 0,
            config: entries[0].config,
            predicted_latency_ms: entries[0].latency_ms,
            predicted_energy_j: entries[0].energy_j,
            latency_ms: entries[0].latency_ms * 1.1,
            energy_j: entries[0].energy_j,
            edge_energy_j: entries[0].energy_j / 2.0,
            cloud_energy_j: entries[0].energy_j / 2.0,
            accuracy: 0.95,
        };
        b.bench("runtime_adapt_telemetry_record", || {
            telemetry.record(0, sample);
            telemetry.recorded()
        });
    }

    // --- sharded admission (DESIGN.md §14) ---
    // Routing cost, full offer->drain cycles through 1 vs 4 shards, and
    // the lock-free counter polling the admission gate + adapt loop
    // lean on.  The sharded drain uses the same work-stealing pop the
    // serving workers use.
    {
        use dynasplit::serve::{route_shard, ShardedQueue};
        use dynasplit::workload::TimedRequest;
        let tr = |id: usize| TimedRequest {
            request: Request {
                id,
                net: Network::Vgg16,
                qos_ms: 500.0,
                inferences: 1,
                seed: id as u64,
            },
            arrival_ms: id as f64,
        };
        let mut rid = 0usize;
        b.bench("runtime_scale_route_shard_8", || {
            rid = rid.wrapping_add(1);
            route_shard(rid, 8)
        });
        for shards in [1usize, 4] {
            b.bench(&format!("runtime_scale_offer_drain256_s{shards}"), || {
                let q = ShardedQueue::new(shards, 256);
                for id in 0..256 {
                    q.offer(tr(id));
                }
                q.close();
                let mut drained = 0;
                while q.pop_due_from(0, || None).is_some() {
                    drained += 1;
                }
                drained
            });
        }
        let polled = ShardedQueue::new(4, 256);
        for id in 0..64 {
            polled.offer(tr(id));
        }
        b.bench("runtime_scale_stats_poll_s4", || {
            polled.stats().admitted + polled.depth()
        });
    }

    // --- fault path (DESIGN.md §15) ---
    // `FaultPlan::decide` runs on every dispatch attempt of a chaos
    // run, and the breaker's route/verdict pair brackets every batch in
    // a resilient pipeline — both must stay noise next to inference.
    {
        use dynasplit::fault::{CircuitBreaker, FaultClass, FaultPlan};
        let plan = FaultPlan {
            loss_p: 0.1,
            stall_p: 0.05,
            ..FaultPlan::link_flap(11, 1.0, 60.0, 20.0, 1000.0)
        };
        let cfg =
            Config { net: Network::Vgg16, cpu_idx: 6, tpu: TpuMode::Off, gpu: true, split: 3 };
        let mut fid = 0usize;
        b.bench("runtime_fault_plan_decide", || {
            fid = fid.wrapping_add(1);
            let r = Request {
                id: fid % 1000,
                net: Network::Vgg16,
                qos_ms: 500.0,
                inferences: 1,
                seed: fid as u64,
            };
            plan.decide(&r, &cfg, 1).is_some()
        });
        let mut brk = CircuitBreaker::new(3, 8);
        let mut flip = false;
        b.bench("runtime_fault_breaker_route_verdict", || {
            flip = !flip;
            let route = brk.route();
            if flip {
                brk.on_failure(route, FaultClass::CloudLink);
            } else {
                brk.on_success(route, true);
            }
            brk.state()
        });
    }

    // --- flight recorder (DESIGN.md §16) ---
    // One full pipeline run per iteration, recorder off vs live: the
    // observability tax on the serving hot path.  The off arm is the
    // unwired pipeline bit-for-bit (static-dispatch no-op); the on arm
    // must stay within the <5% acceptance budget, enforced in CI via
    // DYNASPLIT_BENCH_ENFORCE_OBS=<max on/off ratio>.
    {
        use dynasplit::adapt::StoreMap;
        use dynasplit::controller::{ExecOutcome, PaperPolicy};
        use dynasplit::obs::Recorder;
        use dynasplit::serve::{run_pipeline_resilient, PipelineConfig, RetryPolicy};
        use dynasplit::workload::TimedRequest;

        struct FixedExec;
        impl Executor for FixedExec {
            fn execute(&mut self, request: &Request, _config: &Config) -> ExecOutcome {
                ExecOutcome {
                    latency_ms: 40.0 + (request.seed % 5) as f64,
                    energy_j: 1.5,
                    edge_energy_j: 0.5,
                    cloud_energy_j: 1.0,
                    accuracy: 0.95,
                }
            }
        }

        let cfg_of = |split: usize| Config {
            net: Network::Vgg16,
            cpu_idx: 6,
            tpu: TpuMode::Off,
            gpu: true,
            split,
        };
        let store = ConfigStore::new(ConfigSet::new(vec![
            ParetoEntry { config: cfg_of(3), latency_ms: 45.0, energy_j: 1.5, accuracy: 0.95 },
            ParetoEntry { config: cfg_of(22), latency_ms: 80.0, energy_j: 5.0, accuracy: 0.95 },
        ]));
        let tl: Vec<TimedRequest> = (0..256)
            .map(|i| TimedRequest {
                request: Request {
                    id: i,
                    net: Network::Vgg16,
                    qos_ms: 500.0,
                    inferences: 1,
                    seed: i as u64,
                },
                arrival_ms: i as f64,
            })
            .collect();
        let cfg = PipelineConfig {
            workers: 2,
            queue_capacity: 256,
            max_batch: 4,
            time_scale: 0.0,
            seed: 7,
            reuse: true,
            shards: 1,
            discrete: false,
        };
        let run = |recorder: &Recorder| {
            let stores = StoreMap::broadcast(&store);
            run_pipeline_resilient(
                &stores,
                &PaperPolicy,
                &tl,
                &cfg,
                None,
                None,
                RetryPolicy::none(),
                None,
                recorder,
                |_| Ok(FixedExec),
            )
            .expect("obs bench run")
            .completed()
        };
        b.bench("runtime_obs_pipeline_off", || run(&dynasplit::obs::OFF));
        b.bench("runtime_obs_pipeline_on", || {
            let recorder = Recorder::flight(cfg.workers, cfg.shards, 1 << 12);
            let done = run(&recorder);
            done + recorder.take().map_or(0, |t| t.len())
        });
        let ratio = b.speedup("runtime_obs_pipeline_on", "runtime_obs_pipeline_off");
        if let Some(r) = ratio {
            println!(
                "    >> flight-recorder on/off overhead: {:+.1}% (target < 5%)",
                (r - 1.0) * 100.0
            );
        }
        if let Ok(ceiling) = std::env::var("DYNASPLIT_BENCH_ENFORCE_OBS") {
            let ceiling: f64 =
                ceiling.parse().expect("DYNASPLIT_BENCH_ENFORCE_OBS must be a number");
            let r = ratio.expect(
                "DYNASPLIT_BENCH_ENFORCE_OBS needs both runtime_obs_pipeline_* cases \
                 (check the filter)",
            );
            assert!(
                r <= ceiling,
                "recorder on/off ratio {r:.3} above enforced ceiling {ceiling}"
            );
            println!("    >> enforced: {r:.3} <= {ceiling}");
        }
    }

    // --- NSGA machinery ---
    let objs: Vec<[f64; 3]> = (0..200)
        .map(|_| [rng.f64() * 1000.0, rng.f64() * 100.0, -rng.f64()])
        .collect();
    b.bench("non_dominated_sort_200", || sort::non_dominated_fronts(&objs).len());
    b.bench("das_dennis_p12", || refpoints::das_dennis(12).len());

    // --- transport framing ---
    let payload: Vec<f32> = (0..16_384).map(|i| i as f32).collect();
    b.bench("frame_encode_64KiB_tensor", || Frame::tensor(&payload).encode().len());
    let encoded = Frame::tensor(&payload).encode();
    b.bench("frame_decode_64KiB_tensor", || {
        Frame::decode(&encoded).unwrap().unwrap().1
    });

    // --- JSON / manifest ---
    if let Ok(text) = std::fs::read_to_string("artifacts/manifest.json") {
        b.bench("json_parse_manifest", || Json::parse(&text).unwrap());
    }

    // --- cost model ---
    b.bench("netcost_tables", || {
        NetCost::of(Network::Vgg16).total_macs() + NetCost::of(Network::Vit).total_macs()
    });

    // --- real backend path (artifacts + XLA required) ---
    // These benches characterize the production PJRT hot path; pointing
    // them at the scalar reference interpreter would both crawl and
    // measure nothing the reproduction cares about.
    match (Manifest::load(&dynasplit::artifacts_dir(None)), dynasplit::runtime::default_backend()) {
        (Ok(manifest), Ok(backend)) if backend.name() == "xla" => {
            let vgg = dynasplit::runtime::NetworkRuntime::load(
                backend.as_ref(),
                &manifest,
                Network::Vgg16,
            )
            .unwrap();
            let (images, _) = manifest.load_eval_set().unwrap();
            let x = &images[..manifest.batch * manifest.img * manifest.img * 3];
            let tag = backend.name();
            b.bench(&format!("{tag}_vgg_layer0_batch16"), || {
                vgg.run_range(0, 1, false, x).unwrap().len()
            });
            b.bench(&format!("{tag}_vgg_full_forward_batch16"), || {
                vgg.run_full(0, x).unwrap().len()
            });
            b.bench(&format!("{tag}_vgg_int8_head11_batch16"), || {
                vgg.run_head(11, true, x).unwrap().len()
            });
        }
        (manifest, backend) => {
            let backend_note = match &backend {
                Ok(b) if b.name() != "xla" => "not xla (build with --features xla)",
                Ok(_) => "ok",
                Err(_) => "unavailable",
            };
            println!(
                "(runtime benches skipped: manifest {}, backend {backend_note})",
                if manifest.is_ok() { "ok" } else { "missing — run `make artifacts`" },
            );
        }
    }
    b.finish();
}
