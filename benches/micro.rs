//! Micro benchmarks of the coordinator's hot paths (§Perf L3):
//! trial simulation, NSGA-III machinery, meter integration, transport
//! framing, JSON parsing, and — when artifacts are present — the real
//! PJRT layer execution path.

use dynasplit::controller::algorithm1::{self, SelectIndex};
use dynasplit::model::{Manifest, NetCost};
use dynasplit::nsga::{refpoints, sort};
use dynasplit::runtime::InferenceBackend;
use dynasplit::simulator::meter::{Meter, PowerTrace};
use dynasplit::simulator::Testbed;
use dynasplit::solver::ParetoEntry;
use dynasplit::space::{Network, Space};
use dynasplit::transport::frame::Frame;
use dynasplit::util::bench::Bencher;
use dynasplit::util::json::Json;
use dynasplit::util::rng::Pcg32;

fn main() {
    let mut b = Bencher::from_env();
    let tb = Testbed::synthetic();
    let space = Space::new(Network::Vgg16);
    let mut rng = Pcg32::seeded(1);

    // --- simulator ---
    let configs: Vec<_> = (0..64).map(|_| space.sample(&mut rng)).collect();
    let mut ci = 0;
    b.bench("testbed_trial_1000_inferences", || {
        ci = (ci + 1) % configs.len();
        tb.run_trial_n(&configs[ci], 1000, &mut rng).latency_ms
    });
    b.bench("device_latency_model", || {
        ci = (ci + 1) % configs.len();
        tb.vgg.latency(&configs[ci]).total_s()
    });

    // --- meter ---
    let mut trace = PowerTrace::new();
    for i in 0..2000 {
        trace.push(0.2, 3.0 + (i % 7) as f64 * 0.3);
    }
    let meter = Meter::edge();
    b.bench("meter_sample_2000seg_trace", || meter.measure_energy_j(&trace, &mut rng));

    // --- Algorithm-1 selection: O(n) scan vs O(log n) index ---
    // The paper's set holds ~12-15 entries; production-scale stores can
    // hold thousands.  Same QoS sequence for both variants at each n.
    for &n in &[100usize, 1_000, 10_000] {
        let mut entries: Vec<ParetoEntry> = (0..n)
            .map(|_| ParetoEntry {
                config: space.sample(&mut rng),
                latency_ms: rng.uniform(50.0, 5000.0),
                energy_j: rng.uniform(1.0, 100.0),
                accuracy: rng.uniform(0.9, 1.0),
            })
            .collect();
        algorithm1::sort_config_set(&mut entries);
        let index = SelectIndex::build(&entries);
        let qos: Vec<f64> = (0..256).map(|_| rng.uniform(10.0, 6000.0)).collect();
        let mut qi = 0;
        b.bench(&format!("select_scan_n{n}"), || {
            qi = (qi + 1) % qos.len();
            algorithm1::select_pos(&entries, qos[qi])
        });
        let mut qj = 0;
        b.bench(&format!("select_index_n{n}"), || {
            qj = (qj + 1) % qos.len();
            index.select(qos[qj])
        });
        b.bench(&format!("select_index_build_n{n}"), || {
            SelectIndex::build(&entries).len()
        });
    }

    // --- NSGA machinery ---
    let objs: Vec<[f64; 3]> = (0..200)
        .map(|_| [rng.f64() * 1000.0, rng.f64() * 100.0, -rng.f64()])
        .collect();
    b.bench("non_dominated_sort_200", || sort::non_dominated_fronts(&objs).len());
    b.bench("das_dennis_p12", || refpoints::das_dennis(12).len());

    // --- transport framing ---
    let payload: Vec<f32> = (0..16_384).map(|i| i as f32).collect();
    b.bench("frame_encode_64KiB_tensor", || Frame::tensor(&payload).encode().len());
    let encoded = Frame::tensor(&payload).encode();
    b.bench("frame_decode_64KiB_tensor", || {
        Frame::decode(&encoded).unwrap().unwrap().1
    });

    // --- JSON / manifest ---
    if let Ok(text) = std::fs::read_to_string("artifacts/manifest.json") {
        b.bench("json_parse_manifest", || Json::parse(&text).unwrap());
    }

    // --- cost model ---
    b.bench("netcost_tables", || {
        NetCost::of(Network::Vgg16).total_macs() + NetCost::of(Network::Vit).total_macs()
    });

    // --- real backend path (artifacts + XLA required) ---
    // These benches characterize the production PJRT hot path; pointing
    // them at the scalar reference interpreter would both crawl and
    // measure nothing the reproduction cares about.
    match (Manifest::load(&dynasplit::artifacts_dir(None)), dynasplit::runtime::default_backend()) {
        (Ok(manifest), Ok(backend)) if backend.name() == "xla" => {
            let vgg = dynasplit::runtime::NetworkRuntime::load(
                backend.as_ref(),
                &manifest,
                Network::Vgg16,
            )
            .unwrap();
            let (images, _) = manifest.load_eval_set().unwrap();
            let x = &images[..manifest.batch * manifest.img * manifest.img * 3];
            let tag = backend.name();
            b.bench(&format!("{tag}_vgg_layer0_batch16"), || {
                vgg.run_range(0, 1, false, x).unwrap().len()
            });
            b.bench(&format!("{tag}_vgg_full_forward_batch16"), || {
                vgg.run_full(0, x).unwrap().len()
            });
            b.bench(&format!("{tag}_vgg_int8_head11_batch16"), || {
                vgg.run_head(11, true, x).unwrap().len()
            });
        }
        (manifest, backend) => {
            let backend_note = match &backend {
                Ok(b) if b.name() != "xla" => "not xla (build with --features xla)",
                Ok(_) => "ok",
                Err(_) => "unavailable",
            };
            println!(
                "(runtime benches skipped: manifest {}, backend {backend_note})",
                if manifest.is_ok() { "ok" } else { "missing — run `make artifacts`" },
            );
        }
    }
    b.finish();
}
