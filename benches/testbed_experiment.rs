//! Bench: regenerate the Testbed Experiment — Fig. 6 (scheduling
//! decisions), Fig. 7 (latency), Fig. 8 (QoS violations), Fig. 9
//! (energy), and the headline energy-reduction / QoS-met numbers.

use dynasplit::experiments::{testbed_exp, Ctx};
use dynasplit::space::Network;
use dynasplit::util::bench::Bencher;

fn main() {
    let mut b = Bencher::from_env();
    let ctx = Ctx::load(&dynasplit::artifacts_dir(None));
    for net in Network::ALL {
        b.run_once(&format!("fig6_to_9_testbed_{}", net.name()), || {
            let exp = testbed_exp::run(&ctx, net, 50, 1000, 42);
            testbed_exp::print_report(&exp);
        });
    }
    b.finish();
}
