//! Fixture-driven rule tests.
//!
//! Every rule ships at least a positive (`bad.rs`, expected violations)
//! and a negative (`good.rs`, zero violations) fixture under
//! `tests/fixtures/<rule>/`.  The first line of each fixture declares
//! the *virtual* repo-relative path — which drives rule scoping — and
//! the expected diagnostic count for that rule:
//!
//! ```text
//! // dslint-fixture: rust/src/serve/dispatch.rs expect=3
//! ```
//!
//! Fixtures are scanned, never compiled, so they can encode violations
//! that would not build (and claim any path in the repo).

use std::fs;
use std::path::{Path, PathBuf};

fn fixtures_root() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures")
}

fn header(path: &Path, text: &str) -> (String, usize) {
    let line = text.lines().next().unwrap_or("");
    let rest = line
        .strip_prefix("// dslint-fixture:")
        .unwrap_or_else(|| panic!("{}: first line must be a dslint-fixture header", path.display()))
        .trim();
    let (virtual_path, expect) = rest
        .split_once(" expect=")
        .unwrap_or_else(|| panic!("{}: header needs ` expect=N`", path.display()));
    let expect = expect
        .trim()
        .parse()
        .unwrap_or_else(|_| panic!("{}: expect= must be a count", path.display()));
    (virtual_path.trim().to_string(), expect)
}

fn sorted_entries(dir: &Path) -> Vec<PathBuf> {
    let mut entries: Vec<PathBuf> = fs::read_dir(dir)
        .unwrap_or_else(|e| panic!("read {}: {e}", dir.display()))
        .filter_map(|e| e.ok().map(|e| e.path()))
        .collect();
    entries.sort();
    entries
}

#[test]
fn fixtures_match_expected_counts() {
    let mut checked = 0usize;
    for rule_dir in sorted_entries(&fixtures_root()) {
        let rule = rule_dir.file_name().unwrap().to_string_lossy().into_owned();
        assert!(
            dslint::RULES.iter().any(|(n, _)| *n == rule),
            "fixture dir {rule} does not name a known rule"
        );
        for file in sorted_entries(&rule_dir) {
            if file.extension().and_then(|e| e.to_str()) != Some("rs") {
                continue;
            }
            let text = fs::read_to_string(&file).unwrap();
            let (virtual_path, expect) = header(&file, &text);
            let diags = dslint::scan_source(&virtual_path, &text);
            let hits: Vec<_> = diags.iter().filter(|d| d.rule == rule).collect();
            assert_eq!(
                hits.len(),
                expect,
                "{} (as {virtual_path}): expected {expect} `{rule}` diagnostics, got {:#?}",
                file.display(),
                diags
            );
            checked += 1;
        }
    }
    assert!(checked >= 18, "only {checked} fixtures checked — fixture set shrank");
}

#[test]
fn every_rule_has_positive_and_negative_fixtures() {
    let root = fixtures_root();
    for (rule, _) in dslint::RULES {
        let dir = root.join(rule);
        for case in ["bad.rs", "good.rs"] {
            let path = dir.join(case);
            assert!(path.is_file(), "rule {rule} is missing its {case} fixture");
        }
        // and the positive fixture must actually expect violations
        let bad = fs::read_to_string(dir.join("bad.rs")).unwrap();
        let (_, expect) = header(&dir.join("bad.rs"), &bad);
        assert!(expect >= 1, "rule {rule}: bad.rs must expect at least one violation");
    }
}
