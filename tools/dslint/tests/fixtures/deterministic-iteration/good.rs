// dslint-fixture: rust/src/serve/report.rs expect=0
use std::collections::BTreeMap;

/// BTreeMap iterates in key order: the digest is stable run to run.
pub struct Report {
    pub per_worker: BTreeMap<usize, u64>,
}
