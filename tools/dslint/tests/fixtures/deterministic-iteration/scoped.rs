// dslint-fixture: rust/src/workload/mod.rs expect=0
use std::collections::HashMap;

/// HashMap is fine outside the digest/report modules — this rule is
/// path-scoped, not global.
pub fn histogram(xs: &[u64]) -> HashMap<u64, u64> {
    let mut h = HashMap::new();
    for &x in xs {
        *h.entry(x).or_insert(0) += 1;
    }
    h
}
