// dslint-fixture: rust/src/serve/report.rs expect=2
use std::collections::HashMap;

/// Iterating this map to print the per-worker digest would make the
/// report line ordering depend on the hasher seed.
pub struct Report {
    pub per_worker: HashMap<usize, u64>,
}
