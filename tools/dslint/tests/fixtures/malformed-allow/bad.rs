// dslint-fixture: rust/src/workload/mod.rs expect=2

// dslint::allow(no-thread-spawn)
pub const MISSING_REASON: u32 = 1;

// dslint::allow(not-a-rule): a reason does not rescue an unknown rule
pub const UNKNOWN_RULE: u32 = 2;
