// dslint-fixture: rust/src/workload/mod.rs expect=0

// dslint::allow(no-thread-spawn): well-formed escape with a reason;
// harmless even when nothing below it violates the rule
pub const SANCTIONED: u32 = 1;
