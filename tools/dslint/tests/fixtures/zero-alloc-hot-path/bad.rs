// dslint-fixture: rust/src/runtime/kernels.rs expect=2

/// The `_into` suffix promises the caller owns every buffer — yet this
/// body allocates a scratch Vec and clones the input on the hot path.
pub fn gemm_into(a: &[f32], out: &mut [f32]) {
    let mut scratch = Vec::new();
    scratch.extend_from_slice(a);
    let copy = a.to_vec();
    let n = copy.len().min(out.len());
    out[..n].copy_from_slice(&copy[..n]);
}
