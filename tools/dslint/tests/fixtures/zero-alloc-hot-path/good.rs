// dslint-fixture: rust/src/runtime/kernels.rs expect=0

/// Allocation-free: every buffer, scratch included, is caller-owned.
pub fn gemm_into(a: &[f32], scratch: &mut [f32], out: &mut [f32]) {
    let n = a.len().min(scratch.len()).min(out.len());
    scratch[..n].copy_from_slice(&a[..n]);
    out[..n].copy_from_slice(&scratch[..n]);
}

/// Allocating helpers are fine outside `*_in`/`*_into` names — the rule
/// binds the signature's promise, not the whole module.
pub fn gemm(a: &[f32]) -> Vec<f32> {
    a.to_vec()
}
