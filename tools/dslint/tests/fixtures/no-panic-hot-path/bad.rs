// dslint-fixture: rust/src/serve/dispatch.rs expect=3

pub fn dispatch(slot: Option<usize>, outs: &[f64]) -> f64 {
    let idx = slot.unwrap();
    let out = outs.get(idx).expect("bound");
    if out.is_nan() {
        panic!("nan outcome");
    }
    *out
}
