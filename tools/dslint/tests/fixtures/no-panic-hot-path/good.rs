// dslint-fixture: rust/src/serve/dispatch.rs expect=0

/// Shed-not-crash: the serving stack degrades a bad dispatch to a shed
/// outcome instead of panicking the worker.
pub fn dispatch(slot: Option<usize>, outs: &[f64]) -> Option<f64> {
    let idx = slot?;
    outs.get(idx).copied()
}

#[cfg(test)]
mod tests {
    #[test]
    fn test_harness_may_unwrap() {
        let v = super::dispatch(Some(0), &[1.0]).unwrap();
        assert!((v - 1.0).abs() < 1e-12);
    }
}
