// dslint-fixture: rust/src/serve/worker.rs expect=0

/// The sanctioned channels: record a TraceEvent for in-flight state,
/// return data for post-hoc state — never write to stdout from the
/// serving stack ("println" inside a string is not a call).
pub fn dispatch(recorder: &Recorder, id: usize, now: Option<f64>) -> &'static str {
    recorder.emit_worker(0, now, EventKind::Dispatched { id, worker: 0, batch: 1 });
    "println"
}

#[cfg(test)]
mod tests {
    #[test]
    fn test_code_may_print() {
        println!("fixture debugging output is fine here");
        eprintln!("and on stderr too");
    }
}
