// dslint-fixture: rust/src/serve/worker.rs expect=3

pub fn dispatch(id: usize, depth: usize) -> usize {
    println!("dispatching request {id}");
    if depth > 100 {
        eprintln!("queue deep: {depth}");
    }
    dbg!(id + depth)
}
