// dslint-fixture: rust/src/transport/relay.rs expect=2
//
// Two unbounded retry loops: a bare `loop` that re-dispatches a failed
// batch forever, and a `while let` that drains a channel with no
// deadline.  Both spin forever under a persistent fault — the exact
// failure mode DESIGN.md §15's taxonomy calls LinkDown.

fn redispatch(ex: &mut dyn Executor, reqs: &[&Request], cfg: &Config) -> Vec<ExecOutcome> {
    loop {
        match ex.try_execute_batch(reqs, cfg) {
            Ok(outs) => return outs,
            Err(_) => continue, // no attempt cap, no budget charge
        }
    }
}

fn drain(rx: &Receiver<Frame>) -> usize {
    let mut n = 0;
    while let Ok(frame) = rx.recv() {
        consume(frame);
        n += 1;
    }
    n
}
