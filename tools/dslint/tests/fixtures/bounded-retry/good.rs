// dslint-fixture: rust/src/transport/relay.rs expect=0
//
// The sanctioned shapes: an attempt-capped retry loop that charges a
// backoff penalty against the remaining QoS budget, a receive loop
// driven by a deadline, and a loop with no re-dispatch call at all.

fn redispatch(ex: &mut dyn Executor, reqs: &[&Request], cfg: &Config) -> Option<Vec<ExecOutcome>> {
    let max_attempts = 4;
    let mut attempt = 0;
    loop {
        attempt += 1;
        match ex.try_execute_batch(reqs, cfg) {
            Ok(outs) => return Some(outs),
            Err(_) if attempt >= max_attempts => return None,
            Err(_) => continue,
        }
    }
}

fn drain(rx: &Receiver<Frame>, deadline: WallDeadline) -> usize {
    let mut n = 0;
    while let Some(remaining) = deadline.remaining() {
        match rx.recv_timeout(remaining) {
            Ok(frame) => {
                consume(frame);
                n += 1;
            }
            Err(_) => break,
        }
    }
    n
}

fn no_dispatch(xs: &[u32]) -> u32 {
    let mut sum = 0;
    for x in xs {
        sum += x; // loops without re-dispatch calls are out of scope
    }
    sum
}
