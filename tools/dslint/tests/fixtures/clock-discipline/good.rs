// dslint-fixture: rust/src/serve/clock.rs expect=0
//
// serve/clock.rs is the sanctioned wall-clock seam: the only place
// (plus util/bench.rs) allowed to read Instant::now directly.
use std::time::Instant;

pub struct Stopwatch {
    t0: Instant,
}

impl Stopwatch {
    pub fn start() -> Stopwatch {
        Stopwatch { t0: Instant::now() }
    }

    pub fn elapsed_ms(&self) -> f64 {
        self.t0.elapsed().as_secs_f64() * 1e3
    }
}
