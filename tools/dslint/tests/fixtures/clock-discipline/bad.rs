// dslint-fixture: rust/src/controller/mod.rs expect=2
use std::time::{Instant, SystemTime};

pub fn overhead_ms() -> f64 {
    let t0 = Instant::now();
    let _wall = SystemTime::now();
    t0.elapsed().as_secs_f64() * 1e3
}
