// dslint-fixture: rust/src/transport/link.rs expect=1
use std::sync::mpsc::Sender;
use std::sync::Mutex;

pub fn pump(stats: &Mutex<u64>, tx: &Sender<u64>) {
    let count = stats.lock().ok();
    tx.send(1).ok();
}
