// dslint-fixture: rust/src/transport/link.rs expect=0
use std::sync::mpsc::Sender;
use std::sync::{Condvar, Mutex};

/// Snapshot under the lock, drop the guard, then block.
pub fn pump(stats: &Mutex<u64>, tx: &Sender<u64>) {
    let count = stats.lock().ok();
    let snapshot = count.as_deref().copied().unwrap_or(0);
    drop(count);
    tx.send(snapshot).ok();
}

/// Condvar waits *consume* the guard — that hand-off is the sanctioned
/// blocking-with-a-guard pattern.
pub fn drain(q: &Mutex<u64>, cv: &Condvar) {
    let inner = q.lock().ok();
    let _woken = cv.wait(inner);
}
