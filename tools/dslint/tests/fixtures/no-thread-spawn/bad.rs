// dslint-fixture: rust/src/runtime/pool.rs expect=1
use std::thread;

pub fn start() -> thread::JoinHandle<()> {
    thread::spawn(|| {})
}
