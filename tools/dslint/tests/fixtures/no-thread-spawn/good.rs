// dslint-fixture: rust/src/runtime/pool.rs expect=0
use std::thread;

/// Scoped threads join structurally — the sanctioned default.
pub fn fan_out(xs: &mut [u64]) {
    thread::scope(|scope| {
        for x in xs.iter_mut() {
            scope.spawn(move || *x += 1);
        }
    });
}

pub fn detached() -> thread::JoinHandle<()> {
    // dslint::allow(no-thread-spawn): the handle is owned by the caller,
    // which joins it in shutdown() — see DESIGN.md §13
    thread::spawn(|| {})
}
