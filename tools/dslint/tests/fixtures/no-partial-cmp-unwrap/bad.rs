// dslint-fixture: rust/src/solver/mod.rs expect=1

/// Sorting energies with partial_cmp panics the moment a NaN reaches
/// the comparator (the PR-2 solver crash this rule memorializes).
pub fn best(xs: &[f64]) -> f64 {
    let mut v: Vec<f64> = xs.into();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    v[0]
}
