// dslint-fixture: rust/src/solver/mod.rs expect=0

/// total_cmp is a total order over every f64 bit pattern — NaN sorts,
/// nothing panics.  The lexer must also ignore "a.partial_cmp(b)" here
/// (comment) and below (string literal).
pub fn best(xs: &[f64]) -> f64 {
    let mut v: Vec<f64> = xs.into();
    v.sort_by(|a, b| a.total_cmp(b));
    debug_assert!(!v.is_empty(), "never sort via partial_cmp");
    v[0]
}
