// dslint-fixture: benches/micro.rs expect=0
use dynasplit::util::rng::Pcg32;

/// Literal base seed plus a structural stream id: replays bit-identically.
pub fn stream(worker: u64) -> Pcg32 {
    Pcg32::new(0x5eed_5eed, worker)
}
