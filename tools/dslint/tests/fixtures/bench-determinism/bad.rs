// dslint-fixture: benches/micro.rs expect=1
use dynasplit::serve::Stopwatch;
use dynasplit::util::rng::Pcg32;

/// A time-derived seed makes every rerun sample a different stream —
/// the figure scripts would never replay bit-identically.
pub fn jitter() -> u64 {
    let sw = Stopwatch::start();
    let mut rng = Pcg32::seeded(sw.elapsed().as_nanos() as u64);
    rng.next_u64()
}
