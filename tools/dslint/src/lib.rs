//! dslint — repo-invariant linter for the DynaSplit serving stack.
//!
//! A zero-dependency, token-level scanner.  It is *not* a Rust parser:
//! the lexer blanks comments and string/char literals (so sites inside
//! them never match), then a tiny tokenizer turns the rest into
//! ident/punct tokens that the rules pattern-match against.  That is
//! enough to enforce the repo invariants catalogued in DESIGN.md §13
//! with rustc-style `file:line:col` diagnostics, without pulling syn or
//! the clippy toolchain into an offline build.
//!
//! Rule scoping keys on *repo-relative* paths (`rust/src/serve/...`),
//! which is how both the CLI (run from the repo root) and the fixture
//! tests (virtual paths) feed files in.
//!
//! Escape hatch: a violation is suppressed by
//! `// dslint::allow(rule-name): reason` on the same line or anywhere
//! in the contiguous `//` comment block directly above it.  The reason
//! is mandatory — an allow without one (or naming an unknown rule) is
//! itself a `malformed-allow` violation.

use std::fmt;

/// Every enforced rule, with the one-line summary `--rules` prints.
pub const RULES: &[(&str, &str)] = &[
    (
        "no-partial-cmp-unwrap",
        "float ordering goes through total_cmp, never partial_cmp (NaN panics)",
    ),
    (
        "clock-discipline",
        "Instant::now/SystemTime::now only in serve/clock.rs and util/bench.rs; \
         everyone else uses Stopwatch/WallDeadline/ServeClock",
    ),
    (
        "no-panic-hot-path",
        "no unwrap/expect/panic!/todo!/unimplemented! in non-test code under \
         serve/, adapt/, runtime/kernels.rs (shed, don't crash)",
    ),
    (
        "deterministic-iteration",
        "no HashMap/HashSet in modules whose iteration order reaches reports \
         or digests; use BTreeMap/BTreeSet or Vec",
    ),
    (
        "zero-alloc-hot-path",
        "no Vec::new/vec!/to_vec/clone/collect inside `*_in`/`*_into` \
         functions — those signatures promise caller-owned buffers",
    ),
    (
        "guard-across-blocking",
        "a mutex/rwlock guard must be dropped before send/recv/join/wait on \
         the same scope's channels or threads",
    ),
    (
        "no-thread-spawn",
        "std::thread::spawn forbidden; use thread::scope so joins are \
         structural (documented owner-joined handles may allow-escape)",
    ),
    (
        "bench-determinism",
        "Pcg32 seeds must be literals or config — never derived from elapsed \
         time (reruns must replay bit-identically)",
    ),
    (
        "bounded-retry",
        "loops that re-dispatch (try_execute_batch/recv/recv_timeout) must \
         reference a deadline/budget/attempt symbol — unbounded retry loops \
         spin forever when the fault is persistent",
    ),
    (
        "no-print-hot-path",
        "println!/eprintln!/print!/eprint!/dbg! banned in non-test serve/, adapt/, \
         fault/, obs/ code; the flight recorder and reports are the observability \
         channels, stdout belongs to the CLI",
    ),
    (
        "malformed-allow",
        "dslint::allow(...) escapes must name a known rule and give a reason",
    ),
];

/// One violation, rendered rustc-style as `file:line:col: rule: message`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Diagnostic {
    pub file: String,
    pub line: usize,
    pub col: usize,
    pub rule: &'static str,
    pub message: String,
}

impl fmt::Display for Diagnostic {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}:{}: {}: {}", self.file, self.line, self.col, self.rule, self.message)
    }
}

fn rule_name(name: &str) -> Option<&'static str> {
    RULES.iter().map(|(n, _)| *n).find(|n| *n == name)
}

// ---------------------------------------------------------------------------
// Lexer: blank comments and literals, collect allow-escapes.
// ---------------------------------------------------------------------------

struct Stripped {
    /// Same byte length as the input, with every comment and string/char
    /// literal byte (except newlines) replaced by a space, so token
    /// positions in `code` are positions in the original text.
    code: Vec<u8>,
    /// `(byte_pos_of_comment, rule)` for each well-formed allow.
    allows: Vec<(usize, &'static str)>,
    /// Byte positions of malformed `dslint::allow` escapes.
    malformed: Vec<usize>,
}

/// Parse `dslint::allow(rule): reason` out of one comment's text.
/// Returns `Ok(Some(rule))` for a well-formed allow, `Ok(None)` when the
/// comment has no allow at all, `Err(())` when an allow is present but
/// malformed (unknown rule, or missing `: reason`).
fn parse_allow(comment: &str) -> Result<Option<&'static str>, ()> {
    const NEEDLE: &str = "dslint::allow(";
    let Some(at) = comment.find(NEEDLE) else {
        return Ok(None);
    };
    let rest = &comment[at + NEEDLE.len()..];
    let Some(close) = rest.find(')') else {
        return Err(());
    };
    let name = rest[..close].trim();
    if name.is_empty() || !name.bytes().all(|b| b.is_ascii_lowercase() || b.is_ascii_digit() || b == b'-') {
        return Err(());
    }
    let Some(rule) = rule_name(name) else {
        return Err(());
    };
    let tail = rest[close + 1..].trim_start();
    let Some(reason) = tail.strip_prefix(':') else {
        return Err(());
    };
    if reason.trim().is_empty() {
        return Err(());
    }
    Ok(Some(rule))
}

fn strip(text: &str) -> Stripped {
    let b = text.as_bytes();
    let mut code = b.to_vec();
    let mut allows = Vec::new();
    let mut malformed = Vec::new();
    let n = b.len();
    let mut i = 0;

    // Blank bytes [from, to) except newlines (position-preserving).
    let blank = |code: &mut [u8], from: usize, to: usize| {
        for p in from..to {
            if code[p] != b'\n' {
                code[p] = b' ';
            }
        }
    };

    while i < n {
        let c = b[i];
        if c == b'/' && i + 1 < n && b[i + 1] == b'/' {
            // Line comment: scan to end of line, parse any allow-escape.
            let start = i;
            while i < n && b[i] != b'\n' {
                i += 1;
            }
            let comment = &text[start..i];
            match parse_allow(comment) {
                Ok(Some(rule)) => allows.push((start, rule)),
                Ok(None) => {}
                Err(()) => malformed.push(start),
            }
            blank(&mut code, start, i);
        } else if c == b'/' && i + 1 < n && b[i + 1] == b'*' {
            // Block comment, nesting like rustc.
            let start = i;
            let mut depth = 1usize;
            i += 2;
            while i < n && depth > 0 {
                if b[i] == b'/' && i + 1 < n && b[i + 1] == b'*' {
                    depth += 1;
                    i += 2;
                } else if b[i] == b'*' && i + 1 < n && b[i + 1] == b'/' {
                    depth -= 1;
                    i += 2;
                } else {
                    i += 1;
                }
            }
            blank(&mut code, start, i);
        } else if (c == b'r' || c == b'b')
            && raw_string_open(b, i).is_some()
        {
            // Raw string r"...", r#"..."#, br#"..."# — no escapes; closed
            // by a quote followed by the same number of hashes.
            let (body_start, hashes) = raw_string_open(b, i).unwrap();
            let start = i;
            i = body_start;
            loop {
                if i >= n {
                    break;
                }
                if b[i] == b'"' && b[i + 1..].len() >= hashes
                    && b[i + 1..i + 1 + hashes].iter().all(|&h| h == b'#')
                {
                    i += 1 + hashes;
                    break;
                }
                i += 1;
            }
            blank(&mut code, start, i);
        } else if c == b'"' || (c == b'b' && i + 1 < n && b[i + 1] == b'"') {
            // Plain or byte string with backslash escapes.
            let start = i;
            i += if c == b'b' { 2 } else { 1 };
            while i < n {
                if b[i] == b'\\' {
                    i += 2;
                } else if b[i] == b'"' {
                    i += 1;
                    break;
                } else {
                    i += 1;
                }
            }
            let end = i.min(n);
            blank(&mut code, start, end);
            i = end;
        } else if c == b'\'' {
            // Char literal vs lifetime: '\...' or 'c' (third byte a close
            // quote) is a literal; anything else is a lifetime, left alone.
            if i + 1 < n && b[i + 1] == b'\\' {
                let start = i;
                i += 2; // skip the backslash'd byte
                while i < n && b[i] != b'\'' {
                    i += 1;
                }
                i = (i + 1).min(n);
                blank(&mut code, start, i);
            } else if i + 2 < n && b[i + 2] == b'\'' {
                blank(&mut code, i, i + 3);
                i += 3;
            } else {
                i += 1;
            }
        } else {
            i += 1;
        }
    }

    Stripped { code, allows, malformed }
}

/// `Some((body_start, n_hashes))` when position `i` opens a raw string.
fn raw_string_open(b: &[u8], i: usize) -> Option<(usize, usize)> {
    let mut j = i;
    if b[j] == b'b' {
        j += 1;
    }
    if j >= b.len() || b[j] != b'r' {
        return None;
    }
    j += 1;
    let mut hashes = 0usize;
    while j < b.len() && b[j] == b'#' {
        hashes += 1;
        j += 1;
    }
    if j < b.len() && b[j] == b'"' {
        Some((j + 1, hashes))
    } else {
        None
    }
}

fn line_col(text: &[u8], pos: usize) -> (usize, usize) {
    let mut line = 1;
    let mut col = 1;
    for &c in &text[..pos.min(text.len())] {
        if c == b'\n' {
            line += 1;
            col = 1;
        } else {
            col += 1;
        }
    }
    (line, col)
}

// ---------------------------------------------------------------------------
// Tokenizer over blanked code.
// ---------------------------------------------------------------------------

#[derive(Debug, Clone, Copy)]
struct Tok {
    start: usize,
    end: usize,
    /// 0 for an identifier/number token, otherwise the punct byte.
    punct: u8,
}

fn tokenize(code: &[u8]) -> Vec<Tok> {
    let mut toks = Vec::new();
    let mut i = 0;
    let n = code.len();
    while i < n {
        let c = code[i];
        if c.is_ascii_whitespace() {
            i += 1;
        } else if c.is_ascii_alphanumeric() || c == b'_' {
            let start = i;
            while i < n && (code[i].is_ascii_alphanumeric() || code[i] == b'_') {
                i += 1;
            }
            toks.push(Tok { start, end: i, punct: 0 });
        } else {
            toks.push(Tok { start: i, end: i + 1, punct: c });
            i += 1;
        }
    }
    toks
}

// ---------------------------------------------------------------------------
// Scan context: path scoping, test regions, allow-aware emission.
// ---------------------------------------------------------------------------

struct Ctx<'a> {
    rel: &'a str,
    code: &'a [u8],
    toks: &'a [Tok],
    /// 1-indexed: is this raw source line a `//`-comment line (for the
    /// upward allow walk)?
    comment_line: Vec<bool>,
    /// `(line, rule)` of each well-formed allow.
    allows: Vec<(usize, &'static str)>,
    /// Byte spans of `#[cfg(test)] mod ... { ... }` regions.
    test_spans: Vec<(usize, usize)>,
    diags: Vec<Diagnostic>,
}

impl<'a> Ctx<'a> {
    fn ident(&self, i: usize) -> &'a [u8] {
        match self.toks.get(i) {
            Some(t) if t.punct == 0 => &self.code[t.start..t.end],
            _ => b"",
        }
    }

    fn is_ident(&self, i: usize, s: &str) -> bool {
        i < self.toks.len() && self.ident(i) == s.as_bytes()
    }

    fn is_punct(&self, i: usize, c: u8) -> bool {
        i < self.toks.len() && self.toks[i].punct == c
    }

    fn in_test_span(&self, pos: usize) -> bool {
        self.test_spans.iter().any(|&(a, b)| pos >= a && pos < b)
    }

    fn is_test_file(&self) -> bool {
        self.rel.starts_with("rust/tests/") || self.rel.contains("/fixtures/")
    }

    /// True when `pos` is exempt from rules that only bind production code.
    fn is_test_code(&self, pos: usize) -> bool {
        self.is_test_file() || self.in_test_span(pos)
    }

    fn allowed_at(&self, line: usize, rule: &str) -> bool {
        self.allows.iter().any(|&(l, r)| l == line && r == rule)
    }

    fn emit(&mut self, pos: usize, rule: &'static str, message: String) {
        let (line, col) = line_col(self.code, pos);
        // Same-line allow (trailing comment), then walk up through the
        // contiguous `//` comment block directly above the flagged line.
        if self.allowed_at(line, rule) {
            return;
        }
        let mut l = line;
        while l > 1 {
            l -= 1;
            if !self.comment_line.get(l).copied().unwrap_or(false) {
                break;
            }
            if self.allowed_at(l, rule) {
                return;
            }
        }
        self.diags.push(Diagnostic { file: self.rel.to_string(), line, col, rule, message });
    }

    /// Token index of the `}` matching the `{` at token index `open`.
    fn match_brace(&self, open: usize) -> usize {
        let mut depth = 0i64;
        let mut i = open;
        while i < self.toks.len() {
            match self.toks[i].punct {
                b'{' => depth += 1,
                b'}' => {
                    depth -= 1;
                    if depth == 0 {
                        return i;
                    }
                }
                _ => {}
            }
            i += 1;
        }
        self.toks.len() - 1
    }

    /// Token index of the `)` matching the `(` at token index `open`.
    fn match_paren(&self, open: usize) -> usize {
        let mut depth = 0i64;
        let mut i = open;
        while i < self.toks.len() {
            match self.toks[i].punct {
                b'(' => depth += 1,
                b')' => {
                    depth -= 1;
                    if depth == 0 {
                        return i;
                    }
                }
                _ => {}
            }
            i += 1;
        }
        self.toks.len() - 1
    }
}

/// Byte spans of `#[cfg(test)] mod name { ... }` blocks.
fn test_regions(ctx: &Ctx<'_>) -> Vec<(usize, usize)> {
    let toks = ctx.toks;
    let mut spans = Vec::new();
    let mut i = 0;
    while i + 7 < toks.len() {
        let hit = ctx.is_punct(i, b'#')
            && ctx.is_punct(i + 1, b'[')
            && ctx.is_ident(i + 2, "cfg")
            && ctx.is_punct(i + 3, b'(')
            && ctx.is_ident(i + 4, "test")
            && ctx.is_punct(i + 5, b')')
            && ctx.is_punct(i + 6, b']');
        if !hit {
            i += 1;
            continue;
        }
        let mut j = i + 7;
        if ctx.is_ident(j, "pub") {
            j += 1;
        }
        if !(ctx.is_ident(j, "mod") && j + 1 < toks.len() && toks[j + 1].punct == 0) {
            i += 1;
            continue;
        }
        let mut k = j + 2;
        while k < toks.len() && toks[k].punct != b'{' {
            // tolerate nothing between `mod name` and `{` beyond ws
            break;
        }
        if k < toks.len() && toks[k].punct == b'{' {
            let close = ctx.match_brace(k);
            spans.push((toks[i].start, toks[close].end));
            i = close + 1;
        } else {
            i += 1;
        }
    }
    spans
}

// ---------------------------------------------------------------------------
// Rules.
// ---------------------------------------------------------------------------

const HOT_PATHS: &[&str] = &["rust/src/serve/", "rust/src/adapt/"];
const HOT_FILES: &[&str] = &["rust/src/runtime/kernels.rs"];
const CLOCK_EXEMPT: &[&str] = &["rust/src/serve/clock.rs", "rust/src/util/bench.rs"];
const DIGEST_MODULES: &[&str] = &[
    "rust/src/controller/policy.rs",
    "rust/src/adapt/store.rs",
    "rust/src/serve/report.rs",
    "rust/src/metrics/mod.rs",
    "rust/src/report/mod.rs",
    "rust/src/util/hash.rs",
    "rust/src/obs/event.rs",
    "rust/src/obs/span.rs",
    "rust/src/obs/expose.rs",
    "rust/src/obs/chrome.rs",
];

fn in_hot_path(rel: &str) -> bool {
    HOT_PATHS.iter().any(|p| rel.starts_with(p)) || HOT_FILES.contains(&rel)
}

fn rule_partial_cmp(ctx: &mut Ctx<'_>) {
    for i in 0..ctx.toks.len() {
        if ctx.is_ident(i, "partial_cmp") {
            ctx.emit(
                ctx.toks[i].start,
                "no-partial-cmp-unwrap",
                "float ordering via partial_cmp; use total_cmp (NaN-total, never panics)"
                    .to_string(),
            );
        }
    }
}

fn rule_clock(ctx: &mut Ctx<'_>) {
    if CLOCK_EXEMPT.contains(&ctx.rel) {
        return;
    }
    for i in 0..ctx.toks.len() {
        let which = if ctx.is_ident(i, "Instant") {
            "Instant"
        } else if ctx.is_ident(i, "SystemTime") {
            "SystemTime"
        } else {
            continue;
        };
        if ctx.is_punct(i + 1, b':') && ctx.is_punct(i + 2, b':') && ctx.is_ident(i + 3, "now") {
            ctx.emit(
                ctx.toks[i].start,
                "clock-discipline",
                format!(
                    "{which}::now outside serve/clock.rs; use Stopwatch, WallDeadline or \
                     ServeClock so time is a mockable seam"
                ),
            );
        }
    }
}

fn rule_no_panic(ctx: &mut Ctx<'_>) {
    if !in_hot_path(ctx.rel) {
        return;
    }
    for i in 0..ctx.toks.len() {
        let pos = ctx.toks[i].start;
        if ctx.is_test_code(pos) {
            continue;
        }
        if ctx.is_punct(i, b'.')
            && (ctx.is_ident(i + 1, "unwrap") || ctx.is_ident(i + 1, "expect"))
            && ctx.is_punct(i + 2, b'(')
        {
            let name = String::from_utf8_lossy(ctx.ident(i + 1)).into_owned();
            ctx.emit(
                pos,
                "no-panic-hot-path",
                format!(".{name}() in a hot-path module; shed the request or propagate an error"),
            );
        } else if (ctx.is_ident(i, "panic")
            || ctx.is_ident(i, "todo")
            || ctx.is_ident(i, "unimplemented"))
            && ctx.is_punct(i + 1, b'!')
        {
            let name = String::from_utf8_lossy(ctx.ident(i)).into_owned();
            ctx.emit(
                pos,
                "no-panic-hot-path",
                format!("{name}! in a hot-path module; shed the request or propagate an error"),
            );
        }
    }
}

fn rule_deterministic_iteration(ctx: &mut Ctx<'_>) {
    if !DIGEST_MODULES.contains(&ctx.rel) {
        return;
    }
    for i in 0..ctx.toks.len() {
        let which = if ctx.is_ident(i, "HashMap") {
            "HashMap"
        } else if ctx.is_ident(i, "HashSet") {
            "HashSet"
        } else {
            continue;
        };
        ctx.emit(
            ctx.toks[i].start,
            "deterministic-iteration",
            format!(
                "{which} in a digest/report module; iteration order feeds reports — use \
                 BTreeMap/BTreeSet or a Vec"
            ),
        );
    }
}

fn rule_zero_alloc(ctx: &mut Ctx<'_>) {
    let toks_len = ctx.toks.len();
    let mut i = 0;
    while i < toks_len {
        if !ctx.is_ident(i, "fn") {
            i += 1;
            continue;
        }
        let Some(name_tok) = ctx.toks.get(i + 1) else {
            break;
        };
        if name_tok.punct != 0 {
            i += 1;
            continue;
        }
        let name = String::from_utf8_lossy(&ctx.code[name_tok.start..name_tok.end]).into_owned();
        let hot_sig = name.ends_with("_in") || name.ends_with("_into");
        let sig_ok = ctx.is_punct(i + 2, b'(') || ctx.is_punct(i + 2, b'<');
        if !(hot_sig && sig_ok) {
            i += 1;
            continue;
        }
        // Find the body: first `{` unless a `;` comes first (trait decl).
        let mut j = i + 2;
        let mut open = None;
        while j < toks_len {
            match ctx.toks[j].punct {
                b';' => break,
                b'{' => {
                    open = Some(j);
                    break;
                }
                _ => j += 1,
            }
        }
        let Some(open) = open else {
            i = j + 1;
            continue;
        };
        let close = ctx.match_brace(open);
        let mut hits = Vec::new();
        for k in open..close {
            let pos = ctx.toks[k].start;
            if ctx.is_test_code(pos) {
                continue;
            }
            if ctx.is_ident(k, "Vec")
                && ctx.is_punct(k + 1, b':')
                && ctx.is_punct(k + 2, b':')
                && ctx.is_ident(k + 3, "new")
            {
                hits.push((pos, "Vec::new"));
            } else if ctx.is_ident(k, "vec") && ctx.is_punct(k + 1, b'!') {
                hits.push((pos, "vec!"));
            } else if ctx.is_punct(k, b'.') && ctx.is_ident(k + 1, "to_vec") && ctx.is_punct(k + 2, b'(') {
                hits.push((pos, ".to_vec()"));
            } else if ctx.is_punct(k, b'.') && ctx.is_ident(k + 1, "clone") && ctx.is_punct(k + 2, b'(') {
                hits.push((pos, ".clone()"));
            } else if ctx.is_punct(k, b'.')
                && ctx.is_ident(k + 1, "collect")
                && (ctx.is_punct(k + 2, b'(') || ctx.is_punct(k + 2, b'<') || ctx.is_punct(k + 2, b':'))
            {
                hits.push((pos, ".collect()"));
            }
        }
        for (pos, what) in hits {
            ctx.emit(
                pos,
                "zero-alloc-hot-path",
                format!("{what} inside `{name}`; `*_in`/`*_into` signatures promise the caller \
                         owns every buffer — reuse scratch instead"),
            );
        }
        i = close + 1;
    }
}

const BLOCKING_CALLS: &[&str] = &["send", "recv", "recv_timeout", "join", "wait", "wait_timeout"];

fn rule_guard_across_blocking(ctx: &mut Ctx<'_>) {
    let toks_len = ctx.toks.len();
    let mut i = 0;
    while i < toks_len {
        if !ctx.is_ident(i, "let") {
            i += 1;
            continue;
        }
        let pos = ctx.toks[i].start;
        if ctx.is_test_code(pos) {
            i += 1;
            continue;
        }
        let mut j = i + 1;
        if ctx.is_ident(j, "mut") {
            j += 1;
        }
        if j >= toks_len || ctx.toks[j].punct != 0 {
            i += 1;
            continue;
        }
        let name = String::from_utf8_lossy(&ctx.code[ctx.toks[j].start..ctx.toks[j].end]).into_owned();
        if !ctx.is_punct(j + 1, b'=') {
            i += 1;
            continue;
        }
        // Initializer: scan flat to the terminating `;`; bail if a `{`
        // intervenes (block expressions scope the guard themselves).
        let expr_start = j + 2;
        let mut k = expr_start;
        let mut semi = None;
        while k < toks_len {
            match ctx.toks[k].punct {
                b'{' => break,
                b';' => {
                    semi = Some(k);
                    break;
                }
                _ => k += 1,
            }
        }
        let Some(semi) = semi else {
            i += 1;
            continue;
        };
        // Does the initializer take a lock?  (`.lock()` / `.read()` /
        // `.write()` with empty args — the std sync guard constructors.)
        let mut is_guard = false;
        for g in expr_start..semi {
            if ctx.is_punct(g, b'.')
                && (ctx.is_ident(g + 1, "lock") || ctx.is_ident(g + 1, "read") || ctx.is_ident(g + 1, "write"))
                && ctx.is_punct(g + 2, b'(')
                && ctx.is_punct(g + 3, b')')
            {
                is_guard = true;
                break;
            }
        }
        if !is_guard {
            i += 1;
            continue;
        }
        // Guard scope: from after the `;` to the enclosing block close,
        // truncated at an explicit `drop(name)`.
        let mut depth = 0i64;
        let mut scope_end = toks_len;
        let mut m = semi + 1;
        while m < toks_len {
            match ctx.toks[m].punct {
                b'{' => depth += 1,
                b'}' => {
                    depth -= 1;
                    if depth < 0 {
                        scope_end = m;
                        break;
                    }
                }
                _ => {}
            }
            if ctx.is_ident(m, "drop")
                && ctx.is_punct(m + 1, b'(')
                && ctx.is_ident(m + 2, &name)
                && ctx.is_punct(m + 3, b')')
            {
                scope_end = m;
                break;
            }
            m += 1;
        }
        // Any blocking call in scope that does not *consume* the guard
        // (condvar waits take the guard as an argument — that hand-off
        // is the sanctioned pattern).
        for bidx in (semi + 1)..scope_end {
            if !ctx.is_punct(bidx, b'.') {
                continue;
            }
            let callee = ctx.ident(bidx + 1);
            if !BLOCKING_CALLS.iter().any(|c| callee == c.as_bytes()) {
                continue;
            }
            if !ctx.is_punct(bidx + 2, b'(') {
                continue;
            }
            let close = ctx.match_paren(bidx + 2);
            let consumes_guard =
                ((bidx + 3)..close).any(|a| ctx.is_ident(a, &name));
            if consumes_guard {
                continue;
            }
            let callee = String::from_utf8_lossy(callee).into_owned();
            ctx.emit(
                ctx.toks[bidx].start,
                "guard-across-blocking",
                format!(
                    "`.{callee}(..)` while lock guard `{name}` is live; drop the guard first \
                     (holding a lock across a blocking call deadlocks under contention)"
                ),
            );
            break;
        }
        i = semi + 1;
    }
}

fn rule_no_thread_spawn(ctx: &mut Ctx<'_>) {
    for i in 0..ctx.toks.len() {
        let pos = ctx.toks[i].start;
        if ctx.is_test_code(pos) {
            continue;
        }
        if ctx.is_ident(i, "thread")
            && ctx.is_punct(i + 1, b':')
            && ctx.is_punct(i + 2, b':')
            && ctx.is_ident(i + 3, "spawn")
        {
            ctx.emit(
                pos,
                "no-thread-spawn",
                "thread::spawn detaches the join from the spawn; use thread::scope, or \
                 dslint::allow with the owner that joins the handle"
                    .to_string(),
            );
        }
    }
}

const TIME_IDENTS: &[&str] = &["elapsed", "as_nanos", "as_micros", "as_millis", "now"];

fn rule_bench_determinism(ctx: &mut Ctx<'_>) {
    for i in 0..ctx.toks.len() {
        if !(ctx.is_ident(i, "Pcg32")
            && ctx.is_punct(i + 1, b':')
            && ctx.is_punct(i + 2, b':')
            && (ctx.is_ident(i + 3, "new") || ctx.is_ident(i + 3, "seeded"))
            && ctx.is_punct(i + 4, b'('))
        {
            continue;
        }
        let close = ctx.match_paren(i + 4);
        let time_seeded = ((i + 5)..close).any(|a| {
            TIME_IDENTS.iter().any(|t| ctx.is_ident(a, t))
        });
        if time_seeded {
            ctx.emit(
                ctx.toks[i].start,
                "bench-determinism",
                "Pcg32 seeded from wall-clock time; seeds must be literals or config so \
                 every run replays bit-identically"
                    .to_string(),
            );
        }
    }
}

/// Calls whose presence makes a loop a *retry loop*: they re-dispatch
/// work that already failed (executor batches) or block on a peer that
/// may never answer (transport receives).
const RETRY_CALLS: &[&str] = &["try_execute_batch", "recv", "recv_timeout"];

/// Identifiers that witness a bound on the loop: a deadline or budget
/// being consumed, an attempt counter being compared, or an expiry
/// check.  Token-exact matches — `recv_timeout` the *call* does not
/// satisfy the rule, but a `timeout` variable fed to it does.
const BUDGET_IDENTS: &[&str] = &[
    "deadline",
    "budget",
    "remaining",
    "remaining_ms",
    "timeout",
    "attempt",
    "attempts",
    "max_attempts",
    "tries",
    "max_tries",
    "expired",
];

fn rule_bounded_retry(ctx: &mut Ctx<'_>) {
    let toks_len = ctx.toks.len();
    let mut i = 0;
    while i < toks_len {
        let is_loop = ctx.is_ident(i, "loop");
        let is_headed = ctx.is_ident(i, "while") || ctx.is_ident(i, "for");
        if !(is_loop || is_headed) {
            i += 1;
            continue;
        }
        let kw_pos = ctx.toks[i].start;
        if ctx.is_test_code(kw_pos) {
            i += 1;
            continue;
        }
        // Find the body `{`.  For `while`/`for`, scan past the header,
        // skipping parenthesized groups so closure bodies inside call
        // arguments (`while xs.any(|x| { .. })`) don't open the loop
        // early.  A `;` before any `{` means this wasn't a loop header.
        let mut j = i + 1;
        let mut open = None;
        while j < toks_len {
            match ctx.toks[j].punct {
                b'{' => {
                    open = Some(j);
                    break;
                }
                b'(' => j = ctx.match_paren(j) + 1,
                b';' => break,
                _ => j += 1,
            }
        }
        let Some(open) = open else {
            i += 1;
            continue;
        };
        let close = ctx.match_brace(open);
        // A retry loop is one whose span (header + body) method-calls a
        // re-dispatch primitive.  The `.` requirement keeps `fn recv(`
        // definitions from matching.
        let mut retry_at = None;
        for k in i..close {
            if ctx.is_punct(k, b'.')
                && RETRY_CALLS.iter().any(|c| ctx.ident(k + 1) == c.as_bytes())
                && ctx.is_punct(k + 2, b'(')
            {
                retry_at = Some((ctx.toks[k].start, k + 1));
                break;
            }
        }
        let Some((pos, callee_idx)) = retry_at else {
            i += 1;
            continue;
        };
        let bounded = (i..close).any(|k| {
            ctx.toks[k].punct == 0
                && BUDGET_IDENTS.iter().any(|b| ctx.ident(k) == b.as_bytes())
        });
        if !bounded {
            let callee = String::from_utf8_lossy(ctx.ident(callee_idx)).into_owned();
            ctx.emit(
                pos,
                "bounded-retry",
                format!(
                    "`.{callee}(..)` inside a loop with no deadline/budget/attempt bound; \
                     a persistent fault spins this forever — charge a deadline, check \
                     remaining budget, or cap attempts (DESIGN.md §15)"
                ),
            );
        }
        i += 1;
    }
}

/// Modules whose non-test code must stay print-free: the serving data
/// plane, the adaptation loop, the fault layer, and the observability
/// layer itself.  A stray `println!` there corrupts exposition output
/// piped to stdout, breaks twin-run byte-comparisons, and hides state
/// from the flight recorder, which is the sanctioned channel.
const PRINT_QUIET_PATHS: &[&str] =
    &["rust/src/serve/", "rust/src/adapt/", "rust/src/fault/", "rust/src/obs/"];

fn rule_no_print(ctx: &mut Ctx<'_>) {
    if !PRINT_QUIET_PATHS.iter().any(|p| ctx.rel.starts_with(p)) {
        return;
    }
    for i in 0..ctx.toks.len() {
        let pos = ctx.toks[i].start;
        if ctx.is_test_code(pos) {
            continue;
        }
        let which = if ctx.is_ident(i, "println") {
            "println"
        } else if ctx.is_ident(i, "eprintln") {
            "eprintln"
        } else if ctx.is_ident(i, "print") {
            "print"
        } else if ctx.is_ident(i, "eprint") {
            "eprint"
        } else if ctx.is_ident(i, "dbg") {
            "dbg"
        } else {
            continue;
        };
        if ctx.is_punct(i + 1, b'!') {
            ctx.emit(
                pos,
                "no-print-hot-path",
                format!(
                    "{which}! in a serving-stack module; record a TraceEvent through the \
                     Recorder (crate::obs) or return data to the caller — stdout is the \
                     CLI's channel, not the pipeline's"
                ),
            );
        }
    }
}

// ---------------------------------------------------------------------------
// Entry points.
// ---------------------------------------------------------------------------

/// Scan one source file.  `rel` is the repo-relative path (it drives
/// rule scoping); `text` is the file contents.
pub fn scan_source(rel: &str, text: &str) -> Vec<Diagnostic> {
    let stripped = strip(text);
    let toks = tokenize(&stripped.code);

    // 1-indexed comment-line map from the *raw* text (the allow walk
    // climbs through `//` lines above a flagged site).
    let mut comment_line = vec![false; text.lines().count() + 2];
    for (idx, raw) in text.lines().enumerate() {
        comment_line[idx + 1] = raw.trim_start().starts_with("//");
    }
    let allows = stripped
        .allows
        .iter()
        .map(|&(pos, rule)| (line_col(text.as_bytes(), pos).0, rule))
        .collect();

    let mut ctx = Ctx {
        rel,
        code: &stripped.code,
        toks: &toks,
        comment_line,
        allows,
        test_spans: Vec::new(),
        diags: Vec::new(),
    };
    ctx.test_spans = test_regions(&ctx);

    rule_partial_cmp(&mut ctx);
    rule_clock(&mut ctx);
    rule_no_panic(&mut ctx);
    rule_deterministic_iteration(&mut ctx);
    rule_zero_alloc(&mut ctx);
    rule_guard_across_blocking(&mut ctx);
    rule_no_thread_spawn(&mut ctx);
    rule_bench_determinism(&mut ctx);
    rule_bounded_retry(&mut ctx);
    rule_no_print(&mut ctx);

    for &pos in &stripped.malformed {
        let (line, col) = line_col(text.as_bytes(), pos);
        ctx.diags.push(Diagnostic {
            file: rel.to_string(),
            line,
            col,
            rule: "malformed-allow",
            message: "dslint::allow must name a known rule and give a reason: \
                      `// dslint::allow(rule-name): why this site is sanctioned`"
                .to_string(),
        });
    }

    let mut diags = ctx.diags;
    diags.sort_by(|a, b| (a.line, a.col, a.rule).cmp(&(b.line, b.col, b.rule)));
    diags
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rules_of(rel: &str, src: &str) -> Vec<&'static str> {
        scan_source(rel, src).into_iter().map(|d| d.rule).collect()
    }

    #[test]
    fn comments_and_strings_never_match() {
        let src = r###"
            // Instant::now() in a comment is fine
            /* and Instant::now() in /* nested */ blocks too */
            fn f() -> &'static str {
                let s = "Instant::now() in a string";
                let r = r#"SystemTime::now() in a raw string"#;
                let b = b"thread::spawn in bytes";
                let c = '"'; // char literal must not open a string
                let t = Instant::now(); // only this one is real
                s
            }
        "###;
        let diags = scan_source("rust/src/workload/mod.rs", src);
        assert_eq!(diags.len(), 1, "{diags:?}");
        assert_eq!(diags[0].rule, "clock-discipline");
        assert_eq!(diags[0].line, 9);
    }

    #[test]
    fn lifetimes_are_not_char_literals() {
        // A lifetime's `'` must not swallow code up to the next quote.
        let src = "fn f<'a>(x: &'a str) -> &'a str { let t = Instant::now(); x }";
        assert_eq!(rules_of("rust/src/workload/mod.rs", src), vec!["clock-discipline"]);
    }

    #[test]
    fn same_line_allow_suppresses() {
        let src = "let t = Instant::now(); // dslint::allow(clock-discipline): boot banner only\n";
        assert!(rules_of("rust/src/workload/mod.rs", src).is_empty());
    }

    #[test]
    fn comment_block_above_allows_multi_line_reasons() {
        let src = "\
// dslint::allow(no-thread-spawn): the handle is owned and joined by
// the executor's shutdown() — see DESIGN.md §13
let h = thread::spawn(move || run());\n";
        assert!(rules_of("rust/src/workload/mod.rs", src).is_empty());
    }

    #[test]
    fn allow_does_not_leak_past_non_comment_lines() {
        let src = "\
// dslint::allow(no-thread-spawn): documented escape
let a = 1;
let h = thread::spawn(move || run());\n";
        assert_eq!(rules_of("rust/src/workload/mod.rs", src), vec!["no-thread-spawn"]);
    }

    #[test]
    fn allow_without_reason_is_malformed_and_does_not_suppress() {
        let src = "\
// dslint::allow(no-thread-spawn)
let h = thread::spawn(move || run());\n";
        let rules = rules_of("rust/src/workload/mod.rs", src);
        assert!(rules.contains(&"malformed-allow"), "{rules:?}");
        assert!(rules.contains(&"no-thread-spawn"), "{rules:?}");
    }

    #[test]
    fn allow_with_unknown_rule_is_malformed() {
        let src = "// dslint::allow(no-such-rule): because\nlet a = 1;\n";
        assert_eq!(rules_of("rust/src/workload/mod.rs", src), vec!["malformed-allow"]);
    }

    #[test]
    fn cfg_test_mod_exempts_hot_path_rules_but_not_clock() {
        let src = "\
#[cfg(test)]
mod tests {
    fn t() {
        let x = opt.unwrap();
        let h = thread::spawn(|| 1);
        let t = Instant::now();
    }
}\n";
        let rules = rules_of("rust/src/serve/foo.rs", src);
        assert_eq!(rules, vec!["clock-discipline"], "{rules:?}");
    }

    #[test]
    fn clock_exempt_files_may_read_the_clock() {
        let src = "pub fn now() -> Instant { Instant::now() }";
        assert!(rules_of("rust/src/serve/clock.rs", src).is_empty());
        assert!(rules_of("rust/src/util/bench.rs", src).is_empty());
        assert_eq!(rules_of("rust/src/util/rng.rs", src), vec!["clock-discipline"]);
    }

    #[test]
    fn trait_method_declarations_have_no_body_to_scan() {
        let src = "trait Sink { fn write_into(&mut self, out: &mut Vec<f32>); }";
        assert!(rules_of("rust/src/runtime/mod.rs", src).is_empty());
    }

    #[test]
    fn condvar_wait_consuming_the_guard_is_sanctioned() {
        let src = "\
fn pump(q: &Queue) {
    let mut inner = q.state.lock().ok();
    inner = q.available.wait(inner);
}\n";
        assert!(rules_of("rust/src/transport/mod.rs", src).is_empty());
    }

    #[test]
    fn diagnostic_renders_rustc_style() {
        let d = Diagnostic {
            file: "rust/src/a.rs".into(),
            line: 3,
            col: 9,
            rule: "clock-discipline",
            message: "msg".into(),
        };
        assert_eq!(d.to_string(), "rust/src/a.rs:3:9: clock-discipline: msg");
    }

    #[test]
    fn unbounded_recv_loop_is_flagged() {
        let src = "\
fn pump(rx: &Receiver<Frame>) {
    loop {
        let f = rx.recv();
        handle(f);
    }
}\n";
        assert_eq!(rules_of("rust/src/transport/pump.rs", src), vec!["bounded-retry"]);
    }

    #[test]
    fn deadline_budgeted_retry_loop_is_sanctioned() {
        let src = "\
fn dispatch(ex: &mut E) {
    let mut attempt = 0;
    loop {
        attempt += 1;
        match ex.try_execute_batch(&reqs, &cfg) {
            Ok(out) => break,
            Err(_) if attempt >= max_attempts => break,
            Err(_) => continue,
        }
    }
}\n";
        assert!(rules_of("rust/src/serve/dispatch.rs", src).is_empty());
    }

    #[test]
    fn while_header_recv_counts_and_timeout_var_bounds_it() {
        let flagged = "fn f(rx: &R) { while let Ok(x) = rx.recv() { eat(x); } }";
        assert_eq!(rules_of("rust/src/transport/x.rs", flagged), vec!["bounded-retry"]);
        let bounded = "fn f(rx: &R) { while let Ok(x) = rx.recv_timeout(timeout) { eat(x); } }";
        assert!(rules_of("rust/src/transport/x.rs", bounded).is_empty());
    }

    #[test]
    fn loops_without_retry_calls_and_test_loops_are_exempt() {
        let plain = "fn f(xs: &[u32]) { for x in xs { push(x); } }";
        assert!(rules_of("rust/src/serve/x.rs", plain).is_empty());
        let test_loop = "\
#[cfg(test)]
mod tests {
    fn t(rx: &R) {
        loop {
            rx.recv().unwrap();
        }
    }
}\n";
        assert!(rules_of("rust/src/transport/x.rs", test_loop).is_empty());
    }

    #[test]
    fn closure_braces_in_a_while_header_do_not_open_the_loop_body() {
        // the `{` inside `.any(|f| { .. })` must not be taken as the loop
        // body — the real body's recv is still in the loop span
        let src = "\
fn f(rx: &R, fs: &[F]) {
    while fs.iter().any(|f| { f.live() }) {
        rx.recv();
    }
}\n";
        assert_eq!(rules_of("rust/src/transport/y.rs", src), vec!["bounded-retry"]);
    }

    #[test]
    fn prints_are_flagged_in_serving_stack_modules_only() {
        let src = "fn f(x: u32) -> u32 { println!(\"{x}\"); dbg!(x) }";
        for rel in [
            "rust/src/serve/worker.rs",
            "rust/src/adapt/mod.rs",
            "rust/src/fault/breaker.rs",
            "rust/src/obs/ring.rs",
        ] {
            assert_eq!(
                rules_of(rel, src),
                vec!["no-print-hot-path", "no-print-hot-path"],
                "{rel}"
            );
        }
        // the CLI and experiment harnesses own stdout
        assert!(rules_of("rust/src/main.rs", src).is_empty());
        assert!(rules_of("rust/src/experiments/chaos.rs", src).is_empty());
    }

    #[test]
    fn test_code_and_allow_escapes_may_print() {
        let test_src = "\
#[cfg(test)]
mod tests {
    fn t() {
        println!(\"debugging a fixture\");
    }
}\n";
        assert!(rules_of("rust/src/serve/worker.rs", test_src).is_empty());
        let allowed =
            "eprintln!(\"boot\"); // dslint::allow(no-print-hot-path): startup banner\n";
        assert!(rules_of("rust/src/serve/mod.rs", allowed).is_empty());
    }

    #[test]
    fn every_rule_table_entry_is_unique() {
        for (i, (a, _)) in RULES.iter().enumerate() {
            for (b, _) in &RULES[i + 1..] {
                assert_ne!(a, b);
            }
        }
        assert!(RULES.len() >= 9);
    }
}
