//! dslint CLI: `cargo run -p dslint -- rust/src rust/tests`
//!
//! Walks the given files/directories (repo-relative, from the repo
//! root — the paths double as rule-scoping keys), prints rustc-style
//! diagnostics for every invariant violation, and exits nonzero when
//! any are found.  `--rules` lists the enforced invariants.

use std::fs;
use std::path::{Path, PathBuf};
use std::process::ExitCode;

fn collect(path: &Path, out: &mut Vec<PathBuf>) -> std::io::Result<()> {
    let meta = fs::metadata(path)?;
    if meta.is_file() {
        if path.extension().is_some_and(|e| e == "rs") {
            out.push(path.to_path_buf());
        }
        return Ok(());
    }
    let mut entries: Vec<PathBuf> =
        fs::read_dir(path)?.filter_map(|e| e.ok().map(|e| e.path())).collect();
    entries.sort();
    for entry in entries {
        collect(&entry, out)?;
    }
    Ok(())
}

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if args.iter().any(|a| a == "--rules") {
        for (name, summary) in dslint::RULES {
            println!("{name}: {summary}");
        }
        return ExitCode::SUCCESS;
    }
    let roots: Vec<String> = if args.is_empty() {
        vec!["rust/src".to_string(), "rust/tests".to_string()]
    } else {
        args
    };

    let mut files = Vec::new();
    for root in &roots {
        if let Err(err) = collect(Path::new(root), &mut files) {
            eprintln!("dslint: cannot read {root}: {err}");
            return ExitCode::from(2);
        }
    }

    let mut total = 0usize;
    for file in &files {
        // Scoping keys are forward-slash repo-relative paths.
        let rel = file.to_string_lossy().replace('\\', "/");
        let text = match fs::read_to_string(file) {
            Ok(t) => t,
            Err(err) => {
                eprintln!("dslint: cannot read {rel}: {err}");
                return ExitCode::from(2);
            }
        };
        for diag in dslint::scan_source(&rel, &text) {
            println!("{diag}");
            total += 1;
        }
    }

    if total > 0 {
        eprintln!(
            "dslint: {total} violation{} in {} file{} scanned",
            if total == 1 { "" } else { "s" },
            files.len(),
            if files.len() == 1 { "" } else { "s" },
        );
        ExitCode::FAILURE
    } else {
        eprintln!("dslint: clean ({} files scanned)", files.len());
        ExitCode::SUCCESS
    }
}
